//! Parallel-round-engine integration tests on the built-in host backend
//! (these run without AOT artifacts): the same configuration and seed
//! must produce byte-identical metrics at any worker count, and every
//! method must run end to end.

use fedhc::baselines::run_cfedavg;
use fedhc::config::ExperimentConfig;
use fedhc::coordinator::{run_clustered, RunResult, Strategy, Trial};
use fedhc::runtime::{Manifest, ModelRuntime};
use fedhc::sim::engine::Engine;
use fedhc::sim::param_pool::ParamPool;
use fedhc::util::rng::stream_seed;
use fedhc::util::Rng;

fn run_with_workers(workers: usize, strategy: Strategy, rounds: usize) -> RunResult {
    let manifest = Manifest::host();
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = rounds;
    cfg.workers = workers;
    cfg.target_accuracy = None;
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    run_clustered(&mut trial, strategy).unwrap()
}

#[test]
fn metrics_identical_across_worker_counts() {
    let base = run_with_workers(1, Strategy::fedhc(), 6);
    assert_eq!(base.ledger.records.len(), 6);
    for workers in [2usize, 4, 8] {
        let other = run_with_workers(workers, Strategy::fedhc(), 6);
        assert_eq!(
            base.ledger.records.len(),
            other.ledger.records.len(),
            "workers={workers}"
        );
        for (a, b) in base.ledger.records.iter().zip(&other.ledger.records) {
            assert_eq!(a.round, b.round);
            assert!(
                a.time_s == b.time_s
                    && a.energy_j == b.energy_j
                    && a.accuracy == b.accuracy
                    && a.loss == b.loss
                    && a.reclustered == b.reclustered,
                "workers={workers}: nondeterministic metrics at round {} \
                 ({:?} vs {:?})",
                a.round,
                a,
                b
            );
        }
        assert_eq!(base.ledger.reclusters, other.ledger.reclusters);
        assert_eq!(base.ledger.maml_adaptations, other.ledger.maml_adaptations);
        assert_eq!(base.final_accuracy, other.final_accuracy);
    }
}

#[test]
fn host_backend_learns_on_tiny() {
    let res = run_with_workers(0, Strategy::fedhc(), 10);
    let first = res.ledger.records.first().unwrap().accuracy;
    let best = res.final_accuracy;
    assert!(best >= first, "accuracy regressed: {first} -> {best}");
    assert!(best > 0.25, "host backend failed to learn: best {best}");
    assert!(res.ledger.time_s > 0.0 && res.ledger.energy_j > 0.0);
}

#[test]
fn all_clustered_strategies_run_on_host_backend() {
    for strategy in [
        Strategy::fedhc(),
        Strategy::fedhc_no_maml(),
        Strategy::hbase(),
        Strategy::fedce(),
    ] {
        let res = run_with_workers(2, strategy, 4);
        assert_eq!(res.ledger.records.len(), 4, "{}", res.name);
        assert!(res.ledger.time_s.is_finite() && res.ledger.time_s > 0.0);
        assert!(res.ledger.energy_j.is_finite() && res.ledger.energy_j > 0.0);
    }
}

#[test]
fn cfedavg_runs_and_is_deterministic_on_host_backend() {
    let run = |workers: usize| {
        let manifest = Manifest::host();
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 4;
        cfg.workers = workers;
        cfg.target_accuracy = None;
        let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
        let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
        run_cfedavg(&mut trial).unwrap()
    };
    let a = run(1);
    let b = run(8);
    assert_eq!(a.ledger.records.len(), 4);
    for (x, y) in a.ledger.records.iter().zip(&b.ledger.records) {
        assert!(x.time_s == y.time_s && x.accuracy == y.accuracy);
    }
}

#[test]
fn pooled_buffers_do_not_perturb_determinism() {
    // jobs overwrite pooled parameter buffers (exactly as the local-train
    // scatter does): results must be identical at any worker count and on
    // a warm pool, because every take is fully overwritten before use —
    // which recycled allocation a task receives is schedule-dependent,
    // the numbers it computes are not
    let pool = ParamPool::new(512);
    let model: Vec<f32> = (0..512).map(|i| (i as f32 * 0.01).sin()).collect();
    let tasks: Vec<u64> = (0..40).collect();
    let run = |w: usize| {
        Engine::new(w).run(&tasks, |_, &t| {
            let mut buf = pool.take_copy(&model);
            let mut rng = Rng::new(stream_seed(7, 3, t));
            for v in buf.iter_mut() {
                *v += rng.uniform_f32();
            }
            let sum: f64 = buf.iter().map(|&x| x as f64).sum();
            pool.put(buf);
            sum
        })
    };
    let base = run(1);
    for w in [2usize, 4, 8] {
        assert_eq!(base, run(w), "pooled buffers perturbed results at w={w}");
    }
    let (fresh, recycled) = pool.stats();
    assert!(recycled > 0, "warm runs must recycle buffers");
    assert!(
        fresh <= 8,
        "fresh allocations bounded by peak concurrency, got {fresh}"
    );
}

#[test]
fn seeds_still_differentiate_runs() {
    let manifest = Manifest::host();
    let run = |seed: u64| {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 5;
        cfg.seed = seed;
        cfg.target_accuracy = None;
        let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
        let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
        run_clustered(&mut trial, Strategy::fedhc()).unwrap()
    };
    let a = run(42);
    let b = run(43);
    assert!(
        a.ledger
            .records
            .iter()
            .zip(&b.ledger.records)
            .any(|(x, y)| x.accuracy != y.accuracy || x.time_s != y.time_s),
        "different seeds produced identical trajectories"
    );
}

//! Scenario-plane acceptance tests (host backend — these always run).
//!
//! The headline claim: the `churn` preset actually exercises the paper's
//! adaptivity loop — dropout rates cross `Z`, re-clustering fires, MAML
//! warm-starts run — and the whole fault trajectory is event-sourced from
//! stateless `(seed, round, sat)` streams, so a scenario run is
//! bit-identical at any `--workers` count.

use fedhc::config::{AggregationMode, ExperimentConfig, Timeline};
use fedhc::coordinator::{run_clustered, run_scenario_matrix, RunResult, Strategy, Trial};
use fedhc::runtime::{Manifest, ModelRuntime};
use fedhc::sim::scenario::{ScenarioConfig, ScenarioEngine, ScenarioKind};

fn run_with(cfg: &ExperimentConfig, strategy: Strategy) -> RunResult {
    let manifest = Manifest::host();
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg.clone(), &manifest, &rt).unwrap();
    run_clustered(&mut trial, strategy).unwrap()
}

fn churn_cfg(workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 12;
    cfg.workers = workers;
    cfg.target_accuracy = None;
    cfg.recluster_threshold = 0.2;
    // the event timeline with a ground pass every round: PSes wait for real
    // visibility windows, so the simulated clock sweeps a meaningful arc of
    // the orbit across the run and re-cluster rebuilds see genuinely
    // drifted geometry (moved members → MAML warm-starts), exactly the
    // dynamic-constellation regime the paper motivates
    cfg.timeline = Timeline::Event;
    cfg.ground_every = 1;
    cfg.scenario = ScenarioConfig::preset(ScenarioKind::Churn);
    cfg
}

/// The acceptance criterion: the churn preset end to end — re-clustering
/// fires, the fault/recluster counters are non-zero, and the full
/// trajectory is bit-identical across `--workers 1` and `--workers 4`.
#[test]
fn churn_preset_fires_recluster_and_is_worker_deterministic() {
    let base = run_with(&churn_cfg(1), Strategy::fedhc());
    assert!(
        base.ledger.reclusters > 0,
        "the churn preset must push some cluster's d_r past Z"
    );
    assert!(
        base.ledger.faults_injected > 0,
        "the churn preset must inject faults"
    );
    assert!(
        base.ledger.maml_adaptations > 0,
        "re-clustering under FedHC must MAML-warm-start moved members"
    );

    let other = run_with(&churn_cfg(4), Strategy::fedhc());
    assert_eq!(base.ledger.records.len(), other.ledger.records.len());
    for (a, b) in base.ledger.records.iter().zip(&other.ledger.records) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.accuracy, b.accuracy, "round {}: accuracy diverged", a.round);
        assert_eq!(a.loss, b.loss, "round {}: loss diverged", a.round);
        assert_eq!(a.time_s, b.time_s, "round {}: time diverged", a.round);
        assert_eq!(a.energy_j, b.energy_j, "round {}: energy diverged", a.round);
        assert_eq!(a.reclustered, b.reclustered, "round {}", a.round);
    }
    assert_eq!(base.ledger.reclusters, other.ledger.reclusters);
    assert_eq!(base.ledger.maml_adaptations, other.ledger.maml_adaptations);
    assert_eq!(base.ledger.faults_injected, other.ledger.faults_injected);
    assert_eq!(base.ledger.straggler_wait_s, other.ledger.straggler_wait_s);
    assert_eq!(base.ledger.stale_passes, other.ledger.stale_passes);
    assert_eq!(base.final_accuracy, other.final_accuracy);
}

/// The aggregation plane rides the same fault plane. The buffered
/// coordinator drives the scenario engine through the identical per-round
/// schedule (`advance_round(r)` is `advance_to(r)` — the conversion pinned
/// property-wise in `proptests.rs`), and with the auto buffer size every
/// present member's upload merges at the last arrival with all-fresh
/// weights. So the whole churn story — onsets, recoveries, dropout rates
/// crossing `Z`, re-cluster rebuilds, MAML warm-starts — replays the sync
/// run bit for bit, while the collection-plane counters prove the
/// buffered machinery (not the sync fast path) actually ran.
#[test]
fn buffered_churn_replays_the_sync_fault_trajectory_bit_exactly() {
    let sync = run_with(&churn_cfg(1), Strategy::fedhc());
    assert!(sync.ledger.reclusters > 0, "the pin needs re-clustering to fire");
    let mut cfg = churn_cfg(1);
    cfg.aggregation = AggregationMode::Buffered;
    let buf = run_with(&cfg, Strategy::fedhc());
    assert_eq!(sync.ledger.records.len(), buf.ledger.records.len());
    for (a, b) in sync.ledger.records.iter().zip(&buf.ledger.records) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.accuracy, b.accuracy, "round {}: accuracy diverged", a.round);
        assert_eq!(a.loss, b.loss, "round {}: loss diverged", a.round);
        assert_eq!(a.time_s, b.time_s, "round {}: time diverged", a.round);
        assert_eq!(a.energy_j, b.energy_j, "round {}: energy diverged", a.round);
        assert_eq!(a.reclustered, b.reclustered, "round {}: recluster diverged", a.round);
    }
    assert_eq!(sync.ledger.faults_injected, buf.ledger.faults_injected);
    assert_eq!(sync.ledger.reclusters, buf.ledger.reclusters);
    assert_eq!(sync.ledger.maml_adaptations, buf.ledger.maml_adaptations);
    assert_eq!(sync.ledger.straggler_wait_s, buf.ledger.straggler_wait_s);
    assert_eq!(sync.ledger.stale_passes, buf.ledger.stale_passes);
    assert_eq!(sync.ledger.ground_wait_s, buf.ledger.ground_wait_s);
    assert_eq!(sync.final_accuracy, buf.final_accuracy);
    // the buffered plane genuinely ran: merges fired, early arrivals idled
    assert!(buf.ledger.buffered_merges > 0);
    assert_eq!(buf.ledger.stale_s, 0.0, "auto buffer size never parks anyone");
}

#[test]
fn straggler_preset_accumulates_wait_and_costs_time() {
    let mut nominal = ExperimentConfig::tiny();
    nominal.rounds = 8;
    nominal.target_accuracy = None;
    // a dropout *rate* can never exceed 1.0: with re-clustering pinned off,
    // the nominal and straggler runs share the exact same topology
    // evolution and the comparison below is airtight
    nominal.recluster_threshold = 1.0;
    let mut straggler = nominal.clone();
    straggler.scenario = ScenarioConfig::preset(ScenarioKind::Stragglers);

    let base = run_with(&nominal, Strategy::fedhc());
    let slow = run_with(&straggler, Strategy::fedhc());
    assert!(
        slow.ledger.straggler_wait_s > 0.0,
        "a 15% straggler rate must slow someone within 8 rounds"
    );
    // slowdowns only stretch member compute times, and the cluster fold is
    // a max over members — simulated time is monotone in the slowdowns
    assert!(
        slow.ledger.time_s >= base.ledger.time_s,
        "straggler time {} fell below nominal {}",
        slow.ledger.time_s,
        base.ledger.time_s
    );
    // the learning trajectory itself is untouched: stragglers are slow,
    // not absent, so accuracies match the nominal run exactly
    for (a, b) in base.ledger.records.iter().zip(&slow.ledger.records) {
        assert_eq!(a.accuracy, b.accuracy, "round {}: slowdown changed learning", a.round);
    }
}

#[test]
fn flaky_ground_preset_stalls_passes_when_the_segment_goes_dark() {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 12;
    cfg.target_accuracy = None;
    cfg.scenario = ScenarioConfig::preset(ScenarioKind::FlakyGround);
    cfg.scenario.ground_outage_prob = 0.6;

    // a single-station ground segment so "every station dark" happens
    // within a few rounds at p = 0.6
    let manifest = Manifest::host();
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg.clone(), &manifest, &rt).unwrap();
    trial.ground.truncate(1);
    trial.scenario = ScenarioEngine::new(
        cfg.scenario,
        cfg.outage_prob,
        cfg.seed,
        cfg.clients,
        trial.ground.len(),
    )
    .unwrap();
    let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
    assert!(
        res.ledger.stale_passes > 0,
        "a 60% per-round station outage must skip some ground pass"
    );
    assert!(res.ledger.faults_injected > 0);
    assert!(res.ledger.records.len() == 12, "the run must still complete");
}

#[test]
fn eclipse_preset_injects_power_save_and_stays_deterministic() {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 6;
    cfg.target_accuracy = None;
    cfg.outage_prob = 0.0; // isolate the eclipse process
    cfg.scenario = ScenarioConfig::preset(ScenarioKind::Eclipse);

    let a = run_with(&cfg, Strategy::fedhc());
    assert!(
        a.ledger.faults_injected > 0,
        "part of a LEO shell is always inside Earth's shadow"
    );
    let mut cfg2 = cfg.clone();
    cfg2.workers = 3;
    let b = run_with(&cfg2, Strategy::fedhc());
    for (x, y) in a.ledger.records.iter().zip(&b.ledger.records) {
        assert_eq!(x.accuracy, y.accuracy);
        assert_eq!(x.time_s, y.time_s);
    }
    assert_eq!(a.ledger.faults_injected, b.ledger.faults_injected);
}

#[test]
fn nominal_preset_reports_only_transient_outages() {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 6;
    cfg.target_accuracy = None;
    cfg.outage_prob = 0.0;
    let res = run_with(&cfg, Strategy::fedhc());
    assert_eq!(
        res.ledger.faults_injected, 0,
        "nominal with zero transient rate must inject nothing"
    );
    assert_eq!(res.ledger.straggler_wait_s, 0.0);
}

/// The recovery plane under fault injection: `noisy-links` bursts corrupt
/// uploads, the detect/retry/backoff loop re-sends and bills, the run
/// completes, and the whole trajectory — including every recovery
/// counter — is bit-identical across worker counts (the corruption draws
/// come from stateless `(seed ^ SALT, round, sender)` streams, never from
/// worker-thread state).
#[test]
fn noisy_links_preset_retransmits_and_is_worker_deterministic() {
    let mk = |workers| {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 10;
        cfg.workers = workers;
        cfg.target_accuracy = None;
        cfg.scenario = ScenarioConfig::preset(ScenarioKind::NoisyLinks);
        // hot bursts (BER up to 5e-2) so corruption is certain in-run
        cfg.scenario.link_noise_ber_nano = 50_000_000;
        cfg
    };
    let a = run_with(&mk(1), Strategy::fedhc());
    assert!(a.ledger.faults_injected > 0, "noise bursts must fire");
    assert!(a.ledger.corrupted_uploads > 0, "bursts must corrupt some upload");
    assert!(a.ledger.retransmits > 0, "corruption must trigger retransmission");
    assert!(a.ledger.retry_wait_s > 0.0, "retries must bill backoff waits");
    assert_eq!(a.ledger.failovers, 0, "this preset crashes no PS process");
    assert_eq!(a.ledger.records.len(), 10, "the noisy run must still complete");

    let b = run_with(&mk(4), Strategy::fedhc());
    assert_eq!(a.ledger.records.len(), b.ledger.records.len());
    for (x, y) in a.ledger.records.iter().zip(&b.ledger.records) {
        assert_eq!(x.accuracy, y.accuracy, "round {}: accuracy diverged", x.round);
        assert_eq!(x.time_s, y.time_s, "round {}: time diverged", x.round);
        assert_eq!(x.energy_j, y.energy_j, "round {}: energy diverged", x.round);
    }
    assert_eq!(a.ledger.retransmits, b.ledger.retransmits);
    assert_eq!(a.ledger.corrupted_uploads, b.ledger.corrupted_uploads);
    assert_eq!(a.ledger.retry_wait_s, b.ledger.retry_wait_s);
    assert_eq!(a.ledger.wire_bytes, b.ledger.wire_bytes);
}

/// The `ps-crash` preset: mid-round PS process crashes promote the
/// next-best backup from the deterministic `rank_cluster_ps` ranking,
/// the ledger counts the promotions, and the trajectory stays
/// bit-identical across worker counts.
#[test]
fn ps_crash_preset_promotes_backups_and_is_worker_deterministic() {
    let mk = |workers| {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 10;
        cfg.workers = workers;
        cfg.target_accuracy = None;
        // failover happens at the pass barrier: exercise it every round
        cfg.ground_every = 1;
        cfg.scenario = ScenarioConfig::preset(ScenarioKind::PsCrash);
        cfg.scenario.ps_fail_prob = 0.5;
        cfg
    };
    let a = run_with(&mk(1), Strategy::fedhc());
    assert!(a.ledger.faults_injected > 0, "PS crashes must fire");
    assert!(a.ledger.failovers > 0, "a crashed PS must promote a backup");
    assert_eq!(a.ledger.records.len(), 10, "the run must survive its PSes");

    let b = run_with(&mk(4), Strategy::fedhc());
    assert_eq!(a.ledger.records.len(), b.ledger.records.len());
    for (x, y) in a.ledger.records.iter().zip(&b.ledger.records) {
        assert_eq!(x.accuracy, y.accuracy, "round {}: accuracy diverged", x.round);
        assert_eq!(x.time_s, y.time_s, "round {}: time diverged", x.round);
        assert_eq!(x.energy_j, y.energy_j, "round {}: energy diverged", x.round);
    }
    assert_eq!(a.ledger.failovers, b.ledger.failovers);
    assert_eq!(a.ledger.stale_passes, b.ledger.stale_passes);
    assert_eq!(a.ledger.wire_bytes, b.ledger.wire_bytes);
}

/// The recovery plane's two bit-identity contracts. With `--ber 0` the
/// retry knobs are inert — even exotic values must not perturb one bit
/// of the nominal trajectory (the coordinator gates the whole plane off
/// before any RNG construction or float op). With a BER floor the plane
/// runs, and every retransmission shows up as extra Eq. 6/7 time, Eq. 8
/// energy, and billed wire traffic.
#[test]
fn zero_ber_is_bit_identical_and_a_ber_floor_bills_recovery_cost() {
    let mut base_cfg = ExperimentConfig::tiny();
    base_cfg.rounds = 8;
    base_cfg.target_accuracy = None;
    // pinned topology evolution so the cost comparison is airtight
    base_cfg.recluster_threshold = 1.0;
    let base = run_with(&base_cfg, Strategy::fedhc());
    assert_eq!(base.ledger.retransmits, 0);
    assert_eq!(base.ledger.corrupted_uploads, 0);
    assert_eq!(base.ledger.retry_wait_s, 0.0);

    let mut gated = base_cfg.clone();
    gated.max_retries = 9;
    gated.retry_backoff = 7.5;
    let same = run_with(&gated, Strategy::fedhc());
    assert_eq!(base.ledger.records.len(), same.ledger.records.len());
    for (x, y) in base.ledger.records.iter().zip(&same.ledger.records) {
        assert_eq!(x.accuracy, y.accuracy, "round {}: retry knobs leaked", x.round);
        assert_eq!(x.time_s, y.time_s, "round {}: retry knobs cost time", x.round);
        assert_eq!(x.energy_j, y.energy_j, "round {}: retry knobs cost energy", x.round);
    }
    assert_eq!(base.ledger.wire_bytes, same.ledger.wire_bytes);
    assert_eq!(same.ledger.retransmits, 0);

    let mut noisy_cfg = base_cfg.clone();
    noisy_cfg.ber = 1e-4;
    let noisy = run_with(&noisy_cfg, Strategy::fedhc());
    assert!(noisy.ledger.retransmits > 0, "a BER floor must corrupt something");
    assert!(noisy.ledger.corrupted_uploads > 0);
    assert!(noisy.ledger.retry_wait_s > 0.0, "retries must bill backoff");
    assert!(
        noisy.ledger.time_s >= base.ledger.time_s,
        "retries cannot make the run faster: {} < {}",
        noisy.ledger.time_s,
        base.ledger.time_s
    );
    assert!(
        noisy.ledger.energy_j > base.ledger.energy_j,
        "each retransmission must bill Eq. 8 uplink energy"
    );
    assert!(
        noisy.ledger.wire_bytes > base.ledger.wire_bytes,
        "each retransmission must be billed on the wire"
    );
}

/// Graceful degradation: a near-certain corruption rate with a single
/// allowed retry exhausts every transfer, so every contribution drops to
/// the stale path — and the run must still complete every round, under
/// both the sync barrier and the buffered event plane (no deadlock, no
/// empty-merge panic).
#[test]
fn retry_exhaustion_degrades_to_stale_path_without_deadlock() {
    for aggregation in [AggregationMode::Sync, AggregationMode::Buffered] {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 6;
        cfg.target_accuracy = None;
        cfg.ber = 0.5; // corrupt_prob ≈ 1 at any real payload size
        cfg.max_retries = 1;
        cfg.aggregation = aggregation;
        let res = run_with(&cfg, Strategy::fedhc());
        assert_eq!(
            res.ledger.records.len(),
            6,
            "{aggregation:?}: exhausted retries must not stall the run"
        );
        assert!(res.ledger.corrupted_uploads > 0, "{aggregation:?}");
        assert!(res.ledger.retransmits > 0, "{aggregation:?}");
    }
}

#[test]
fn scenario_matrix_sweep_covers_every_cell() {
    let manifest = Manifest::host();
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 3;
    cfg.target_accuracy = None;
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let scenarios = [ScenarioKind::Nominal, ScenarioKind::Churn];
    let methods = ["fedhc", "cfedavg"];
    let cells = run_scenario_matrix(&cfg, &manifest, &rt, &scenarios, &methods).unwrap();
    assert_eq!(cells.len(), 4);
    for cell in &cells {
        assert!(
            !cell.result.ledger.records.is_empty(),
            "{}/{} produced no records",
            cell.scenario.name(),
            cell.method
        );
    }
    // the churn cells actually saw faults; the nominal ones saw (at most)
    // transient outages
    let churn_faults: usize = cells
        .iter()
        .filter(|c| c.scenario == ScenarioKind::Churn)
        .map(|c| c.result.ledger.faults_injected)
        .sum();
    assert!(churn_faults > 0, "churn cells must inject faults");
}

//! Clustering bench: the satellite-clustered PS selection algorithm
//! (Eq. 13–15) across constellation sizes and K — it runs on every
//! re-clustering event, so it must stay far off the critical path.
//!
//!     cargo bench --bench bench_clustering

use fedhc::clustering::kmeans::KMeans;
use fedhc::clustering::ps_select::select_parameter_servers;
use fedhc::network::{LinkModel, NetworkParams};
use fedhc::orbit::propagate::Constellation;
use fedhc::orbit::walker::WalkerConstellation;
use fedhc::util::stats::{bench_loop, bench_report};
use fedhc::util::Rng;

fn main() {
    let link = LinkModel::new(NetworkParams::default());
    for &(planes, spp) in &[(4usize, 6usize), (8, 12), (12, 20), (24, 34)] {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(planes, spp));
        let n = c.len();
        let feats = c.snapshot(0.0).features_km();
        let positions = c.snapshot(0.0).positions;
        for &k in &[3usize, 5, 10] {
            if k > n {
                continue;
            }
            let t = bench_loop(2, 20, || {
                let mut rng = Rng::new(7);
                let res = KMeans::new(k).run(&feats, &mut rng);
                std::hint::black_box(&res);
            });
            println!("{}", bench_report(&format!("kmeans n={n} k={k}"), &t));
            let mut rng = Rng::new(7);
            let res = KMeans::new(k).run(&feats, &mut rng);
            let t = bench_loop(2, 20, || {
                let ps = select_parameter_servers(&res, &positions, &link);
                std::hint::black_box(&ps);
            });
            println!("{}", bench_report(&format!("ps_select n={n} k={k}"), &t));
        }
    }
}

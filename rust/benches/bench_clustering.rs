//! Clustering bench: the satellite-clustered PS selection algorithm
//! (Eq. 13–15) across constellation sizes and K — it runs on every
//! re-clustering event, so it must stay far off the critical path.
//!
//! Emits machine-readable `BENCH_clustering.json` at the workspace root
//! (same conventions as `BENCH_runtime.json`: a `mode` field and named
//! entries with ms statistics). `--fast` runs the CI smoke preset.
//!
//!     cargo bench --bench bench_clustering [-- --fast]

use fedhc::clustering::kmeans::KMeans;
use fedhc::clustering::ps_select::select_parameter_servers;
use fedhc::network::{LinkModel, NetworkParams};
use fedhc::orbit::propagate::Constellation;
use fedhc::orbit::walker::WalkerConstellation;
use fedhc::util::json::Json;
use fedhc::util::stats::{bench_loop, bench_report, stats_json};
use fedhc::util::Rng;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let sizes: &[(usize, usize)] = if fast {
        &[(4, 6), (8, 12)]
    } else {
        &[(4, 6), (8, 12), (12, 20), (24, 34)]
    };
    let (warmup, iters) = if fast { (1, 5) } else { (2, 20) };

    let link = LinkModel::new(NetworkParams::default());
    let mut entries: Vec<Json> = Vec::new();
    for &(planes, spp) in sizes {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(planes, spp));
        let n = c.len();
        let feats = c.snapshot(0.0).features_km();
        let positions = c.snapshot(0.0).positions;
        for &k in &[3usize, 5, 10] {
            if k > n {
                continue;
            }
            let t = bench_loop(warmup, iters, || {
                let mut rng = Rng::new(7);
                let res = KMeans::new(k).run(&feats, &mut rng).expect("kmeans");
                std::hint::black_box(&res);
            });
            let name = format!("kmeans n={n} k={k}");
            println!("{}", bench_report(&name, &t));
            entries.push(Json::obj(vec![
                ("name", Json::str(&name)),
                ("n", Json::num(n as f64)),
                ("k", Json::num(k as f64)),
                ("stats", stats_json(&t)),
            ]));
            let mut rng = Rng::new(7);
            let res = KMeans::new(k).run(&feats, &mut rng).expect("kmeans");
            let t = bench_loop(warmup, iters, || {
                let ps = select_parameter_servers(&res, &positions, &link);
                std::hint::black_box(&ps);
            });
            let name = format!("ps_select n={n} k={k}");
            println!("{}", bench_report(&name, &t));
            entries.push(Json::obj(vec![
                ("name", Json::str(&name)),
                ("n", Json::num(n as f64)),
                ("k", Json::num(k as f64)),
                ("stats", stats_json(&t)),
            ]));
        }
    }

    let json = Json::obj(vec![
        ("mode", Json::str(if fast { "fast" } else { "full" })),
        ("entries", Json::Arr(entries)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_clustering.json");
    std::fs::write(path, json.to_pretty() + "\n").expect("write BENCH_clustering.json");
    println!("wrote {path}");
}

//! Fig. 3 bench target: accuracy-vs-round curves for the four methods at
//! K=3 on the tiny preset (fast). Paper-scale curves:
//! `cargo run --release --example fig3_repro mnist 40`.
//!
//!     cargo bench --bench bench_fig3

use fedhc::baselines::run_cfedavg;
use fedhc::config::{AggregationMode, ExperimentConfig};
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::fl::CompressMode;
use fedhc::metrics::report::format_fig3;
use fedhc::metrics::Ledger;
use fedhc::runtime::{Manifest, ModelRuntime};

const METHODS: &[&str] = &["C-FedAvg", "H-BASE", "FedCE", "FedHC"];

fn series(cfg: ExperimentConfig, method: &'static str) -> Ledger {
    let manifest = Manifest::load_or_host(&Manifest::default_dir()).unwrap();
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    match method {
        "C-FedAvg" => run_cfedavg(&mut trial).unwrap().ledger,
        "H-BASE" => run_clustered(&mut trial, Strategy::hbase()).unwrap().ledger,
        "FedCE" => run_clustered(&mut trial, Strategy::fedce()).unwrap().ledger,
        "FedHC" => run_clustered(&mut trial, Strategy::fedhc()).unwrap().ledger,
        _ => unreachable!(),
    }
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let mut base = ExperimentConfig::tiny();
    base.target_accuracy = None;
    base.rounds = if fast { 8 } else { 20 };

    let mut handles = Vec::new();
    for &method in METHODS {
        let cfg = base.clone();
        handles.push((method, std::thread::spawn(move || series(cfg, method))));
    }
    let mut ledgers = Vec::new();
    for (m, h) in handles {
        ledgers.push((m, h.join().expect("worker panicked")));
    }
    let refs: Vec<(&str, &Ledger)> = ledgers.iter().map(|(n, l)| (*n, l)).collect();
    println!("{}", format_fig3("tiny (synthetic)", base.clusters, &refs, 2));

    // qualitative check: FedHC's final accuracy is at least on par with
    // the clustered baselines (within noise) — the paper's Fig. 3 claim
    let acc = |name: &str| {
        ledgers
            .iter()
            .find(|(n, _)| *n == name)
            .unwrap()
            .1
            .best_accuracy()
    };
    let fedhc = acc("FedHC");
    let hbase = acc("H-BASE");
    println!(
        "final: FedHC {:.1}% vs H-BASE {:.1}%",
        fedhc * 100.0,
        hbase * 100.0
    );
    assert!(
        fedhc > hbase - 0.10,
        "FedHC accuracy collapsed vs H-BASE: {fedhc} vs {hbase}"
    );

    // timeline sweep: the same FedHC run under the analytic Eq. 7 folds vs
    // the visibility-gated event timeline (waits are simulated time)
    for timeline in [fedhc::config::Timeline::Analytic, fedhc::config::Timeline::Event] {
        let mut cfg = base.clone();
        cfg.timeline = timeline;
        let ledger = series(cfg, "FedHC");
        println!(
            "timeline {:<8}: time {:>10.0} s  energy {:>8.0} J  waits {:>8.0} s  stale {}",
            timeline.name(),
            ledger.time_s,
            ledger.energy_j,
            ledger.ground_wait_s,
            ledger.stale_passes
        );
    }

    // aggregation sweep: the same FedHC run under each `--aggregation` mode —
    // the idle-vs-stale columns show what a partial buffer trades the
    // synchronous barrier for (FedBuff's staleness discount pays for the
    // reclaimed idle time)
    for (label, mode, buffer) in [
        ("sync", AggregationMode::Sync, 0usize),
        ("buffered", AggregationMode::Buffered, 2),
        ("async", AggregationMode::Async, 0),
    ] {
        let mut cfg = base.clone();
        cfg.aggregation = mode;
        cfg.buffer_size = buffer;
        let ledger = series(cfg, "FedHC");
        println!(
            "aggregation {:<9}: time {:>9.0} s  best acc {:>5.1}%  merges {:>4}  idle {:>8.0} s  stale {:>8.0} s",
            label,
            ledger.time_s,
            ledger.best_accuracy() * 100.0,
            ledger.buffered_merges,
            ledger.idle_s,
            ledger.stale_s
        );
    }

    // wire sweep: the same FedHC run under each `--compress` mode — uplink
    // bytes shrink by the payload ratio while error feedback keeps the
    // accuracy curve close to the dense run
    for (label, mode) in [
        ("none", CompressMode::None),
        ("topk:0.1", CompressMode::TopK(0.1)),
        ("int8", CompressMode::Int8),
    ] {
        let mut cfg = base.clone();
        cfg.compress = mode;
        let ledger = series(cfg, "FedHC");
        let best = ledger.best_accuracy();
        println!(
            "compress {:<9}: time {:>9.0} s  energy {:>8.0} J  best acc {:>5.1}%  \
             wire {:>9.0} B/round",
            label,
            ledger.time_s,
            ledger.energy_j,
            best * 100.0,
            ledger.wire_bytes / base.rounds as f64
        );
        if matches!(mode, CompressMode::None) {
            // the dense sweep leg is the same run as the Fig. 3 FedHC curve
            assert_eq!(
                best.to_bits(),
                fedhc.to_bits(),
                "--compress none drifted from the default FedHC run"
            );
        } else {
            assert!(
                best > fedhc - 0.15,
                "compressed ({label}) accuracy collapsed: {best} vs dense {fedhc}"
            );
        }
    }
}

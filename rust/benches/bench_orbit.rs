//! Orbit substrate bench: snapshot propagation (runs every round) and
//! visibility-window computation (runs at setup / analysis time).
//!
//!     cargo bench --bench bench_orbit

use fedhc::orbit::geo::default_ground_segment;
use fedhc::orbit::propagate::Constellation;
use fedhc::orbit::visibility::{visible_sats, windows};
use fedhc::orbit::walker::WalkerConstellation;
use fedhc::util::stats::{bench_loop, bench_report};

fn main() {
    for &(planes, spp) in &[(8usize, 12usize), (24, 34), (40, 50)] {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(planes, spp));
        let n = c.len();
        let t = bench_loop(3, 100, || {
            let s = c.snapshot(1234.5);
            std::hint::black_box(&s);
        });
        println!("{}", bench_report(&format!("snapshot n={n}"), &t));
    }

    let c = Constellation::from_walker(&WalkerConstellation::paper_shell(8, 12));
    let gs = &default_ground_segment()[0];
    let t = bench_loop(3, 100, || {
        std::hint::black_box(visible_sats(gs, &c, 777.0));
    });
    println!("{}", bench_report("visible_sats n=96", &t));

    let period = c.min_period();
    let t = bench_loop(1, 5, || {
        std::hint::black_box(windows(gs, &c, 0.0, period, 30.0));
    });
    println!("{}", bench_report("windows n=96 one-period", &t));
}

//! Orbit substrate bench: snapshot propagation (runs every round),
//! visibility probing — brute force vs the sphere-grid index, with a
//! bit-identity cross-check — and visibility-window computation.
//!
//! Emits machine-readable `BENCH_orbit.json` at the workspace root (same
//! conventions as `BENCH_runtime.json`). `--fast` runs the CI smoke
//! preset.
//!
//!     cargo bench --bench bench_orbit [-- --fast]

use fedhc::orbit::geo::default_ground_segment;
use fedhc::orbit::index::SphereGrid;
use fedhc::orbit::propagate::Constellation;
use fedhc::orbit::visibility::{visible_sats, visible_sats_indexed, windows};
use fedhc::orbit::walker::WalkerConstellation;
use fedhc::util::json::Json;
use fedhc::util::stats::{bench_loop, bench_report, stats_json};

fn entry(name: &str, n: usize, secs: &[f64]) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("n", Json::num(n as f64)),
        ("stats", stats_json(secs)),
    ])
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let shells: &[(usize, usize)] = if fast {
        &[(8, 12), (24, 34)]
    } else {
        &[(8, 12), (24, 34), (40, 50)]
    };
    let (warmup, iters) = if fast { (1, 20) } else { (3, 100) };
    let mut entries: Vec<Json> = Vec::new();

    for &(planes, spp) in shells {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(planes, spp));
        let n = c.len();
        let t = bench_loop(warmup, iters, || {
            let s = c.snapshot(1234.5);
            std::hint::black_box(&s);
        });
        let name = format!("snapshot n={n}");
        println!("{}", bench_report(&name, &t));
        entries.push(entry(&name, n, &t));

        // visibility probe: brute force vs index (bit-identity asserted)
        let gs = &default_ground_segment()[0];
        let epoch = 777.0;
        let snap = c.snapshot(epoch);
        let grid = SphereGrid::build(&snap.features_km(), SphereGrid::auto_bands(n));
        assert_eq!(
            visible_sats(gs, &c, epoch),
            visible_sats_indexed(gs, &snap, &grid),
            "index diverged from the brute-force visible set"
        );
        let t = bench_loop(warmup, iters, || {
            std::hint::black_box(visible_sats(gs, &c, epoch));
        });
        let name = format!("visible_sats/brute n={n}");
        println!("{}", bench_report(&name, &t));
        entries.push(entry(&name, n, &t));
        let t = bench_loop(warmup, iters, || {
            std::hint::black_box(visible_sats_indexed(gs, &snap, &grid));
        });
        let name = format!("visible_sats/indexed n={n}");
        println!("{}", bench_report(&name, &t));
        entries.push(entry(&name, n, &t));
        // index build alone (features already propagated — the same
        // quantity bench_mega's index_build_ms reports)
        let feats = snap.features_km();
        let t = bench_loop(warmup, iters, || {
            std::hint::black_box(SphereGrid::build(&feats, SphereGrid::auto_bands(n)));
        });
        let name = format!("index_build n={n}");
        println!("{}", bench_report(&name, &t));
        entries.push(entry(&name, n, &t));
    }

    let c = Constellation::from_walker(&WalkerConstellation::paper_shell(8, 12));
    let gs = &default_ground_segment()[0];
    let period = c.min_period();
    let span = if fast { 0.25 * period } else { period };
    let t = bench_loop(1, if fast { 2 } else { 5 }, || {
        std::hint::black_box(windows(gs, &c, 0.0, span, 30.0));
    });
    let name = if fast {
        "windows n=96 quarter-period"
    } else {
        "windows n=96 one-period"
    };
    println!("{}", bench_report(name, &t));
    entries.push(entry(name, c.len(), &t));

    let json = Json::obj(vec![
        ("mode", Json::str(if fast { "fast" } else { "full" })),
        ("entries", Json::Arr(entries)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_orbit.json");
    std::fs::write(path, json.to_pretty() + "\n").expect("write BENCH_orbit.json");
    println!("wrote {path}");
}

//! Aggregation bench: Pallas-kernel (PJRT) vs host weighted-sum across
//! cluster sizes and parameter counts — the data behind the dispatcher
//! threshold in `fl::aggregate` and the §Perf L3 aggregation numbers.
//! The host path is the allocation-free `aggregate_host_into` the round
//! loop now drives through `ModelRuntime::aggregate_into`.
//!
//! Emits machine-readable `BENCH_aggregation.json` at the workspace root
//! alongside `BENCH_runtime.json`.
//!
//!     cargo bench --bench bench_aggregation [-- --fast]

use fedhc::runtime::host::aggregate_host_into;
use fedhc::runtime::{Manifest, ModelRuntime};
use fedhc::util::json::Json;
use fedhc::util::stats::{bench_loop, bench_report, mean};
use fedhc::util::Rng;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let iters = if fast { 10 } else { 50 };
    let mut rng = Rng::new(1);

    // host path scaling: N × P
    println!("== host aggregation (allocation-free weighted sum) ==");
    let mut host_rows = Vec::new();
    for &(n, p) in &[(4usize, 44_426usize), (16, 44_426), (16, 62_006), (64, 44_426), (16, 2_410)] {
        let stack: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..p).map(|_| rng.uniform_f32()).collect())
            .collect();
        let rows: Vec<&[f32]> = stack.iter().map(|r| r.as_slice()).collect();
        let w = vec![1.0 / n as f32; n];
        let mut out = vec![0.0f32; p];
        let t = bench_loop(3, iters, || {
            aggregate_host_into(&rows, &w, &mut out);
        });
        let gb = (n * p * 4) as f64 / 1e9;
        let gbps = gb / mean(&t);
        println!(
            "{}   ({gbps:.2} GB/s)",
            bench_report(&format!("host N={n} P={p}"), &t)
        );
        host_rows.push(Json::obj(vec![
            ("rows", Json::num(n as f64)),
            ("param_count", Json::num(p as f64)),
            ("mean_ms", Json::num(mean(&t) * 1e3)),
            ("gb_per_sec", Json::num(gbps)),
        ]));
    }

    // kernel path (PJRT) vs host at the AOT slot count
    let mut kernel_rows = Vec::new();
    if let Ok(manifest) = Manifest::load(&Manifest::default_dir()) {
        println!("\n== Pallas kernel (PJRT) vs host, per variant ==");
        for name in ["tiny_mlp", "mnist_lenet", "cifar_lenet"] {
            let Ok(rt) = ModelRuntime::load(&manifest, name) else { continue };
            let p = rt.spec.param_count;
            let n = rt.spec.agg_slots;
            let stack: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..p).map(|_| rng.uniform_f32()).collect())
                .collect();
            let rows: Vec<&[f32]> = stack.iter().map(|r| r.as_slice()).collect();
            let w = vec![1.0 / n as f32; n];
            let mut out = Vec::new();
            let t_kernel = bench_loop(2, iters.min(30), || {
                rt.aggregate_into(&rows, &w, &mut out).unwrap();
            });
            println!(
                "{}",
                bench_report(&format!("kernel {name} N={n} P={p}"), &t_kernel)
            );
            let mut host_out = vec![0.0f32; p];
            let t_host = bench_loop(2, iters.min(30), || {
                aggregate_host_into(&rows, &w, &mut host_out);
            });
            println!(
                "{}",
                bench_report(&format!("host   {name} N={n} P={p}"), &t_host)
            );
            kernel_rows.push(Json::obj(vec![
                ("variant", Json::str(name)),
                ("rows", Json::num(n as f64)),
                ("param_count", Json::num(p as f64)),
                ("kernel_mean_ms", Json::num(mean(&t_kernel) * 1e3)),
                ("host_mean_ms", Json::num(mean(&t_host) * 1e3)),
            ]));
        }
    } else {
        eprintln!("no artifacts; skipping kernel comparison");
    }

    let json = Json::obj(vec![
        ("mode", Json::str(if fast { "fast" } else { "full" })),
        ("host", Json::Arr(host_rows)),
        ("kernel_vs_host", Json::Arr(kernel_rows)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_aggregation.json");
    std::fs::write(path, json.to_pretty() + "\n").expect("write BENCH_aggregation.json");
    println!("\nwrote {path}");
}

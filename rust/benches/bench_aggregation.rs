//! Aggregation bench: Pallas-kernel (PJRT) vs host weighted-sum across
//! cluster sizes and parameter counts — the data behind the dispatcher
//! threshold in `fl::aggregate` and the §Perf L3 aggregation numbers.
//!
//!     cargo bench --bench bench_aggregation

use fedhc::runtime::host::aggregate_host_into;
use fedhc::runtime::{Manifest, ModelRuntime};
use fedhc::util::stats::{bench_loop, bench_report};
use fedhc::util::Rng;

fn main() {
    let mut rng = Rng::new(1);

    // host path scaling: N × P
    println!("== host aggregation (allocation-free weighted sum) ==");
    for &(n, p) in &[(4usize, 44_426usize), (16, 44_426), (16, 62_006), (64, 44_426), (16, 2_410)] {
        let stack: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..p).map(|_| rng.uniform_f32()).collect())
            .collect();
        let rows: Vec<&[f32]> = stack.iter().map(|r| r.as_slice()).collect();
        let w = vec![1.0 / n as f32; n];
        let mut out = vec![0.0f32; p];
        let t = bench_loop(3, 50, || {
            aggregate_host_into(&rows, &w, &mut out);
        });
        let gb = (n * p * 4) as f64 / 1e9;
        let mean = t.iter().sum::<f64>() / t.len() as f64;
        println!(
            "{}   ({:.2} GB/s)",
            bench_report(&format!("host N={n} P={p}"), &t),
            gb / mean
        );
    }

    // kernel path (PJRT) vs host at the AOT slot count
    let Ok(manifest) = Manifest::load(&Manifest::default_dir()) else {
        eprintln!("no artifacts; skipping kernel comparison");
        return;
    };
    println!("\n== Pallas kernel (PJRT) vs host, per variant ==");
    for name in ["tiny_mlp", "mnist_lenet", "cifar_lenet"] {
        let Ok(rt) = ModelRuntime::load(&manifest, name) else { continue };
        let p = rt.spec.param_count;
        let n = rt.spec.agg_slots;
        let stack: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..p).map(|_| rng.uniform_f32()).collect())
            .collect();
        let rows: Vec<&[f32]> = stack.iter().map(|r| r.as_slice()).collect();
        let w = vec![1.0 / n as f32; n];
        let t = bench_loop(2, 30, || {
            rt.aggregate(&rows, &w).unwrap();
        });
        println!("{}", bench_report(&format!("kernel {name} N={n} P={p}"), &t));
        let mut out = vec![0.0f32; p];
        let t = bench_loop(2, 30, || {
            aggregate_host_into(&rows, &w, &mut out);
        });
        println!("{}", bench_report(&format!("host   {name} N={n} P={p}"), &t));
    }
}

//! Scenario-matrix bench: all four methods across the seven fault-injection
//! presets (`nominal`, `churn`, `flaky-ground`, `stragglers`, `eclipse`,
//! `noisy-links`, `ps-crash`), at Walker-constellation scale in the full
//! mode and on the tiny smoke preset under `--fast`. Emits
//! machine-readable `BENCH_scenarios.json` at the workspace root so
//! scenario behaviour has a committed trajectory, and asserts the scenario
//! plane's structural claims (panics, never perf thresholds): the churn
//! preset must fire re-clustering and inject faults, the straggler preset
//! must accumulate slowed compute, and the recovery axis below must
//! retransmit corrupted uploads and promote backup PSes.
//! (Cross-preset *time* comparisons live in `tests/scenarios.rs`, where
//! re-clustering is pinned off so topologies stay comparable.)
//!
//!     cargo bench --bench bench_scenarios [-- --fast]

use fedhc::config::{AggregationMode, ExperimentConfig};
use fedhc::coordinator::{run_clustered, run_scenario_matrix, Strategy, Trial};
use fedhc::metrics::report::format_scenario_matrix;
use fedhc::runtime::{Manifest, ModelRuntime};
use fedhc::sim::scenario::ScenarioConfig;
use fedhc::sim::ScenarioKind;
use fedhc::util::json::Json;

const METHODS: [&str; 4] = ["cfedavg", "hbase", "fedce", "fedhc"];

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");

    let mut cfg = ExperimentConfig::tiny();
    cfg.target_accuracy = None;
    // a slightly eager trigger so the churn preset reliably crosses d_r > Z
    // within the short sweep budgets
    cfg.recluster_threshold = 0.2;
    if fast {
        // 12 rounds, not fewer: the seed-42 churn trajectory reaches its
        // partition-independent trigger rounds (>=5 simultaneous failures)
        // at rounds 10-12, which is what makes the recluster assertion
        // below deterministic rather than clustering-dependent
        cfg.rounds = 12;
    } else {
        // Walker scale: the mnist preset's 8×12 shell, on the tiny model
        // so the sweep stays compute-bound on the scenario plane
        cfg.clients = 48;
        cfg.planes = 8;
        cfg.sats_per_plane = 12;
        cfg.rounds = 20;
        cfg.train_samples = 48 * 64;
        cfg.test_samples = 256;
    }

    let manifest = Manifest::load_or_host(&Manifest::default_dir()).expect("manifest");
    let rt = ModelRuntime::load(&manifest, cfg.variant()).expect("runtime");
    println!(
        "== scenario matrix: {} methods x {} presets ({} clients, {} rounds) ==",
        METHODS.len(),
        ScenarioKind::ALL.len(),
        cfg.clients,
        cfg.rounds
    );
    let cells =
        run_scenario_matrix(&cfg, &manifest, &rt, &ScenarioKind::ALL, &METHODS).expect("sweep");

    let rows: Vec<(&str, &str, &fedhc::metrics::Ledger)> = cells
        .iter()
        .map(|c| (c.scenario.name(), c.method, &c.result.ledger))
        .collect();
    println!("{}", format_scenario_matrix(&rows));

    // structural claims — these are correctness assertions, not thresholds
    let cell = |scenario: ScenarioKind, method: &str| {
        cells
            .iter()
            .find(|c| c.scenario == scenario && c.method == method)
            .expect("matrix cell missing")
    };
    let churn_fedhc = cell(ScenarioKind::Churn, "fedhc");
    assert!(
        churn_fedhc.result.ledger.reclusters > 0,
        "the churn preset must fire re-clustering for FedHC"
    );
    assert!(
        churn_fedhc.result.ledger.faults_injected > 0,
        "the churn preset must inject faults"
    );
    let strag_fedhc = cell(ScenarioKind::Stragglers, "fedhc");
    assert!(
        strag_fedhc.result.ledger.straggler_wait_s > 0.0,
        "the straggler preset must accumulate slowed compute"
    );
    assert!(
        cell(ScenarioKind::NoisyLinks, "fedhc").result.ledger.faults_injected > 0,
        "the noisy-links preset must inject noise bursts"
    );
    assert!(
        cell(ScenarioKind::PsCrash, "fedhc").result.ledger.faults_injected > 0,
        "the ps-crash preset must crash PS processes"
    );

    // recovery axis: the matrix above runs the presets at their defaults,
    // where the nano-BER bursts are tuned to Mbit-scale payloads and
    // rarely corrupt the tiny model's ~77-kbit uploads — so the hard
    // retransmit/failover assertions run here, with noise hot enough (and
    // PS crashes frequent enough) that the recovery plane must engage
    println!("== recovery axis: fedhc, retry/backoff + PS failover ==");
    let mut rec_rows = Vec::new();
    for label in ["noisy-links-hot", "ps-crash-hot"] {
        let mut c = cfg.clone();
        if label == "noisy-links-hot" {
            c.scenario = ScenarioConfig::preset(ScenarioKind::NoisyLinks);
            // bursts up to BER 5e-2: corruption is certain at any payload
            c.scenario.link_noise_ber_nano = 50_000_000;
        } else {
            c.scenario = ScenarioConfig::preset(ScenarioKind::PsCrash);
            c.scenario.ps_fail_prob = 0.5;
            c.ground_every = 1;
        }
        let mut trial = Trial::new(c, &manifest, &rt).expect("trial");
        let res = run_clustered(&mut trial, Strategy::fedhc()).expect("recovery-axis run");
        let l = &res.ledger;
        println!(
            "  {label:<16} retx {:>5}   corrupt {:>5}   backoff {:>8.0} s   failov {:>3}   wire {:>13.0} B   time {:>9.0} s   acc {:>5.1}%",
            l.retransmits,
            l.corrupted_uploads,
            l.retry_wait_s,
            l.failovers,
            l.wire_bytes,
            l.time_s,
            res.final_accuracy * 100.0,
        );
        rec_rows.push(Json::obj(vec![
            ("scenario", Json::str(label)),
            ("retransmits", Json::num(l.retransmits as f64)),
            ("corrupted_uploads", Json::num(l.corrupted_uploads as f64)),
            ("retry_wait_s", Json::num(l.retry_wait_s)),
            ("failovers", Json::num(l.failovers as f64)),
            ("wire_bytes", Json::num(l.wire_bytes)),
            ("time_s", Json::num(l.time_s)),
            ("best_accuracy", Json::num(res.final_accuracy)),
        ]));
        if label == "noisy-links-hot" {
            assert!(l.retransmits > 0, "hot noise must trigger retransmissions");
            assert!(l.corrupted_uploads > 0, "hot noise must corrupt uploads");
            assert!(l.retry_wait_s > 0.0, "retries must bill backoff waits");
        } else {
            assert!(l.failovers > 0, "every-pass PS crashes must promote backups");
        }
    }
    println!();

    // aggregation axis: FedHC on the churn preset under each `--aggregation`
    // mode — the idle-vs-stale columns quantify the FedBuff tradeoff (sync
    // and a full buffer idle-wait for every member; small buffers and async
    // merge early and pay in staleness instead)
    println!("== aggregation axis: fedhc x churn, idle vs stale ==");
    let half_cluster = (cfg.clients / cfg.clusters / 2).max(1);
    let mut agg_rows = Vec::new();
    for (label, mode, buffer) in [
        ("sync", AggregationMode::Sync, 0usize),
        ("buffered-auto", AggregationMode::Buffered, 0),
        ("buffered-half", AggregationMode::Buffered, half_cluster),
        ("async", AggregationMode::Async, 0),
    ] {
        let mut c = cfg.clone();
        c.scenario = ScenarioConfig::preset(ScenarioKind::Churn);
        c.aggregation = mode;
        c.buffer_size = buffer;
        let mut trial = Trial::new(c, &manifest, &rt).expect("trial");
        let res = run_clustered(&mut trial, Strategy::fedhc()).expect("aggregation-axis run");
        let stale_n: usize = res.ledger.staleness_hist[1..].iter().sum();
        println!(
            "  {label:<14} time {:>9.0} s   acc {:>5.1}%   merges {:>4}   idle {:>8.0} s   stale {:>8.0} s ({stale_n} stale contributions)",
            res.ledger.time_s,
            res.final_accuracy * 100.0,
            res.ledger.buffered_merges,
            res.ledger.idle_s,
            res.ledger.stale_s,
        );
        agg_rows.push(Json::obj(vec![
            ("mode", Json::str(label)),
            ("buffer_size", Json::num(buffer as f64)),
            ("time_s", Json::num(res.ledger.time_s)),
            ("best_accuracy", Json::num(res.final_accuracy)),
            ("buffered_merges", Json::num(res.ledger.buffered_merges as f64)),
            ("idle_s", Json::num(res.ledger.idle_s)),
            ("stale_s", Json::num(res.ledger.stale_s)),
            ("stale_contributions", Json::num(stale_n as f64)),
        ]));
    }
    println!();

    let json_rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("scenario", Json::str(c.scenario.name())),
                ("method", Json::str(c.method)),
                ("best_accuracy", Json::num(c.result.final_accuracy)),
                ("time_s", Json::num(c.result.ledger.time_s)),
                ("energy_j", Json::num(c.result.ledger.energy_j)),
                ("faults_injected", Json::num(c.result.ledger.faults_injected as f64)),
                ("reclusters", Json::num(c.result.ledger.reclusters as f64)),
                ("maml_adaptations", Json::num(c.result.ledger.maml_adaptations as f64)),
                ("stale_passes", Json::num(c.result.ledger.stale_passes as f64)),
                ("straggler_wait_s", Json::num(c.result.ledger.straggler_wait_s)),
                ("retransmits", Json::num(c.result.ledger.retransmits as f64)),
                ("corrupted_uploads", Json::num(c.result.ledger.corrupted_uploads as f64)),
                ("failovers", Json::num(c.result.ledger.failovers as f64)),
                ("retry_wait_s", Json::num(c.result.ledger.retry_wait_s)),
                ("wire_bytes", Json::num(c.result.ledger.wire_bytes)),
            ])
        })
        .collect();
    let json = Json::obj(vec![
        ("mode", Json::str(if fast { "fast" } else { "full" })),
        ("clients", Json::num(cfg.clients as f64)),
        ("rounds", Json::num(cfg.rounds as f64)),
        ("cells", Json::Arr(json_rows)),
        ("aggregation", Json::Arr(agg_rows)),
        ("recovery", Json::Arr(rec_rows)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_scenarios.json");
    std::fs::write(path, json.to_pretty() + "\n").expect("write BENCH_scenarios.json");
    println!("wrote {path}");
}

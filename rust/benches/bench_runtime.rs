//! Runtime bench: (1) the parallel round engine's threads-vs-wallclock
//! sweep — first over a synthetic local-training-shaped load, then over
//! the *actual* round loop on the host backend — and (2) the per-entry-
//! point PJRT latency numbers when AOT artifacts are present (the §Perf
//! L2/L3 numbers in EXPERIMENTS.md come from the latter).
//!
//!     cargo bench --bench bench_runtime [-- --fast]

use fedhc::config::ExperimentConfig;
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::runtime::{Manifest, ModelRuntime};
use fedhc::sim::engine::Engine;
use fedhc::util::stats::{bench_loop, bench_report, Timer};
use fedhc::util::Rng;

const WORKER_SWEEP: &[usize] = &[1, 2, 4, 8];

/// Scatter-gather over a CPU-bound per-client job (parameter-vector math
/// shaped like one local round), isolating the engine's scaling from the
/// simulator.
fn engine_sweep_synthetic() {
    println!("== engine scatter-gather: workers vs wall-clock (synthetic per-client load) ==");
    let p = 44_426usize; // LeNet-5-sized flat parameter vector
    let tasks: Vec<u64> = (0..48).collect();
    let base: Vec<f32> = (0..p).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut baseline: Option<f64> = None;
    for &w in WORKER_SWEEP {
        let engine = Engine::new(w);
        let timer = Timer::start();
        let sums = engine.run(&tasks, |_, &seed| {
            let mut v = base.clone();
            let mut rng = Rng::new(seed);
            for _ in 0..40 {
                let a = rng.uniform_f32() - 0.5;
                for x in v.iter_mut() {
                    *x = *x * 0.999 + a * 0.001;
                }
            }
            v.iter().map(|&x| x as f64).sum::<f64>()
        });
        std::hint::black_box(&sums);
        let secs = timer.elapsed_secs();
        let base_secs = *baseline.get_or_insert(secs);
        println!(
            "  workers {w:>2}: {:>9.1} ms   speedup x{:.2}",
            secs * 1e3,
            base_secs / secs
        );
    }
}

/// The real thing: `run_clustered` on the host backend, sweeping the
/// engine worker count. Same seed → identical metrics at every width;
/// only the wall-clock changes.
fn engine_sweep_round_loop() {
    println!("\n== full round loop: workers vs wall-clock (host backend, 48 clients, MNIST-geometry) ==");
    let manifest = Manifest::host();
    let mut baseline: Option<f64> = None;
    let mut reference_time: Option<f64> = None;
    for &w in WORKER_SWEEP {
        let mut cfg = ExperimentConfig::mnist();
        cfg.clients = 48;
        cfg.train_samples = 48 * 128;
        cfg.test_samples = 256;
        cfg.rounds = 3;
        cfg.eval_batches = 2;
        cfg.target_accuracy = None;
        cfg.workers = w;
        let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
        let timer = Timer::start();
        let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
        let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
        let secs = timer.elapsed_secs();
        // determinism cross-check while we are here
        match reference_time {
            None => reference_time = Some(res.ledger.time_s),
            Some(t) => assert_eq!(
                t, res.ledger.time_s,
                "worker count changed the simulated metrics!"
            ),
        }
        let base_secs = *baseline.get_or_insert(secs);
        println!(
            "  workers {w:>2}: {:>9.1} ms wall   speedup x{:.2}   (sim time {:.0} s, acc {:.1}%)",
            secs * 1e3,
            base_secs / secs,
            res.ledger.time_s,
            res.final_accuracy * 100.0
        );
    }
}

fn bench_variant(manifest: &Manifest, name: &str, iters: usize) {
    let rt = match ModelRuntime::load(manifest, name) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping {name}: {e}");
            return;
        }
    };
    let spec = &rt.spec;
    let p = spec.param_count;
    let b = spec.batch;
    let d = spec.input_dim();
    let s = spec.chunk_steps;
    let mut rng = Rng::new(1);
    let params = manifest.init_params(spec).unwrap();
    let x: Vec<f32> = (0..b * d).map(|_| rng.uniform_f32()).collect();
    let y: Vec<f32> = (0..b).map(|_| rng.below(10) as f32).collect();
    let xs: Vec<f32> = (0..s * b * d).map(|_| rng.uniform_f32()).collect();
    let ys: Vec<f32> = (0..s * b).map(|_| rng.below(10) as f32).collect();
    let stack: Vec<Vec<f32>> = (0..spec.agg_slots)
        .map(|_| (0..p).map(|_| rng.uniform_f32()).collect())
        .collect();
    let rows: Vec<&[f32]> = stack.iter().map(|r| r.as_slice()).collect();
    let w = vec![1.0 / spec.agg_slots as f32; spec.agg_slots];

    println!("== {name} (P={p}, B={b}) ==");
    let t = bench_loop(2, iters, || {
        rt.train_step(&params, &x, &y, 0.01).unwrap();
    });
    println!("{}", bench_report(&format!("{name}/train_step"), &t));
    let t = bench_loop(2, iters, || {
        rt.train_chunk(&params, &xs, &ys, 0.01).unwrap();
    });
    println!(
        "{}  ({}x steps/call)",
        bench_report(&format!("{name}/train_chunk[{s}]"), &t),
        s
    );
    let t = bench_loop(2, iters, || {
        rt.eval_step(&params, &x, &y).unwrap();
    });
    println!("{}", bench_report(&format!("{name}/eval_step"), &t));
    let t = bench_loop(2, iters, || {
        rt.maml_step(&params, &x, &y, &x, &y, 1e-3, 1e-3).unwrap();
    });
    println!("{}", bench_report(&format!("{name}/maml_step"), &t));
    let t = bench_loop(2, iters, || {
        rt.aggregate(&rows, &w).unwrap();
    });
    println!(
        "{}",
        bench_report(&format!("{name}/aggregate[{}]", spec.agg_slots), &t)
    );
}

fn main() {
    engine_sweep_synthetic();
    engine_sweep_round_loop();

    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("\nno AOT artifacts under {dir:?}; skipping per-entry-point PJRT benches");
        return;
    }
    let manifest = Manifest::load(&dir).expect("artifacts manifest");
    let fast = std::env::args().any(|a| a == "--fast");
    println!();
    bench_variant(&manifest, "tiny_mlp", if fast { 10 } else { 30 });
    bench_variant(&manifest, "mnist_lenet", if fast { 5 } else { 15 });
    bench_variant(&manifest, "cifar_lenet", if fast { 3 } else { 10 });
}

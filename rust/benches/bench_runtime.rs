//! Runtime micro-bench: per-entry-point PJRT latency for each variant.
//! The §Perf L2/L3 numbers in EXPERIMENTS.md come from here.
//!
//!     cargo bench --bench bench_runtime

use fedhc::runtime::{Manifest, ModelRuntime};
use fedhc::util::stats::{bench_loop, bench_report};
use fedhc::util::Rng;

fn bench_variant(manifest: &Manifest, name: &str, iters: usize) {
    let rt = match ModelRuntime::load(manifest, name) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping {name}: {e}");
            return;
        }
    };
    let spec = &rt.spec;
    let p = spec.param_count;
    let b = spec.batch;
    let d = spec.input_dim();
    let s = spec.chunk_steps;
    let mut rng = Rng::new(1);
    let params = manifest.init_params(spec).unwrap();
    let x: Vec<f32> = (0..b * d).map(|_| rng.uniform_f32()).collect();
    let y: Vec<f32> = (0..b).map(|_| rng.below(10) as f32).collect();
    let xs: Vec<f32> = (0..s * b * d).map(|_| rng.uniform_f32()).collect();
    let ys: Vec<f32> = (0..s * b).map(|_| rng.below(10) as f32).collect();
    let stack: Vec<Vec<f32>> = (0..spec.agg_slots)
        .map(|_| (0..p).map(|_| rng.uniform_f32()).collect())
        .collect();
    let rows: Vec<&[f32]> = stack.iter().map(|r| r.as_slice()).collect();
    let w = vec![1.0 / spec.agg_slots as f32; spec.agg_slots];

    println!("== {name} (P={p}, B={b}) ==");
    let t = bench_loop(2, iters, || {
        rt.train_step(&params, &x, &y, 0.01).unwrap();
    });
    println!("{}", bench_report(&format!("{name}/train_step"), &t));
    let t = bench_loop(2, iters, || {
        rt.train_chunk(&params, &xs, &ys, 0.01).unwrap();
    });
    println!(
        "{}  ({}x steps/call)",
        bench_report(&format!("{name}/train_chunk[{s}]"), &t),
        s
    );
    let t = bench_loop(2, iters, || {
        rt.eval_step(&params, &x, &y).unwrap();
    });
    println!("{}", bench_report(&format!("{name}/eval_step"), &t));
    let t = bench_loop(2, iters, || {
        rt.maml_step(&params, &x, &y, &x, &y, 1e-3, 1e-3).unwrap();
    });
    println!("{}", bench_report(&format!("{name}/maml_step"), &t));
    let t = bench_loop(2, iters, || {
        rt.aggregate(&rows, &w).unwrap();
    });
    println!(
        "{}",
        bench_report(&format!("{name}/aggregate[{}]", spec.agg_slots), &t)
    );
}

fn main() {
    let manifest = Manifest::load(&Manifest::default_dir()).expect("run `make artifacts` first");
    let fast = std::env::args().any(|a| a == "--fast");
    bench_variant(&manifest, "tiny_mlp", if fast { 10 } else { 30 });
    bench_variant(&manifest, "mnist_lenet", if fast { 5 } else { 15 });
    bench_variant(&manifest, "cifar_lenet", if fast { 3 } else { 10 });
}

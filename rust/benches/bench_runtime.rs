//! Runtime bench: (1) host MLP kernels — the seed's scalar reference vs
//! the blocked in-place kernels (ns/step, with a bit-identity
//! cross-check), (2) the parallel round engine's threads-vs-wallclock
//! sweep over the *actual* round loop (rounds/sec per worker count),
//! (3) a steady-state allocation audit through a counting global
//! allocator — the round loop must perform **zero parameter-sized
//! allocations per round** (asserted, not a soft threshold), and (4) the
//! per-entry-point PJRT latency numbers when AOT artifacts are present.
//!
//! Emits machine-readable `BENCH_runtime.json` at the workspace root so
//! this and future perf PRs have a committed trajectory.
//!
//!     cargo bench --bench bench_runtime [-- --fast]

use fedhc::config::{AggregationMode, ExperimentConfig};
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::fl::CompressMode;
use fedhc::runtime::host_model::{float_mode, reference};
use fedhc::runtime::{HostModel, HostScratch, Manifest, ModelRuntime};
use fedhc::sim::engine::Engine;
use fedhc::util::json::Json;
use fedhc::util::profile;
use fedhc::util::stats::{bench_loop, bench_report, mean, Timer};
use fedhc::util::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Counting allocator (bench builds only): tracks every allocation on any
/// thread and, above the `PARAM_BYTES` threshold, the parameter-sized ones
/// the steady-state round loop must never perform.
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);
static PARAM_ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);
static PARAM_BYTES: AtomicUsize = AtomicUsize::new(usize::MAX);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        if layout.size() >= PARAM_BYTES.load(Ordering::Relaxed) {
            PARAM_ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Sign-magnitude ulp index, so adjacent floats across the zero crossing
/// are one apart (mirrors the oracle in `runtime::host_model` tests).
fn ulp_index(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 == 0 {
        b as i64
    } else {
        -((b & 0x7fff_ffff) as i64)
    }
}

fn max_ulp(a: &[f32], b: &[f32]) -> u64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (ulp_index(x) - ulp_index(y)).unsigned_abs())
        .max()
        .unwrap_or(0)
}

/// Host MLP hot loop, three generations deep: the seed's scalar
/// `train_step` (allocating, stride-`h` `W1` walk), the blocked in-place
/// kernel (`--strict-float`), and the default SIMD lanes. Cross-checks
/// bit-identity (reference vs blocked) and records the SIMD-vs-strict ulp
/// drift — the design contract pins it at exactly zero — before timing.
fn kernel_before_after(fast: bool) -> Json {
    println!("== host MLP kernels: scalar reference vs blocked vs SIMD ==");
    let manifest = Manifest::host();
    let mut entries: Vec<(&str, Json)> = Vec::new();
    let variants: [(&str, usize); 2] = [
        ("tiny_mlp", if fast { 40 } else { 300 }),
        ("mnist_lenet", if fast { 8 } else { 60 }),
    ];
    for (name, iters) in variants {
        let rt = ModelRuntime::load(&manifest, name).unwrap();
        let m = HostModel::from_spec(&rt.spec).unwrap();
        let params = manifest.init_params(&rt.spec).unwrap();
        let mut rng = Rng::new(1);
        let b = rt.spec.batch;
        let d = rt.spec.input_dim();
        let x: Vec<f32> = (0..b * d).map(|_| rng.uniform_f32()).collect();
        let y: Vec<f32> = (0..b).map(|_| rng.below(10) as f32).collect();

        // the blocked (strict) kernel must match the scalar reference bit
        // for bit, and the SIMD path must match the blocked one
        float_mode::set_strict(true);
        let (p_ref, l_ref) = reference::train_step(&m, &params, &x, &y, 0.01).unwrap();
        let mut p = params.clone();
        let mut scratch = HostScratch::new();
        let l_new = m.train_step_into(&mut p, &x, &y, 0.01, &mut scratch).unwrap();
        assert_eq!(p_ref, p, "{name}: blocked kernel diverged from the scalar reference");
        assert_eq!(l_ref.to_bits(), l_new.to_bits(), "{name}: loss diverged");
        float_mode::set_strict(false);
        let mut p_simd = params.clone();
        let l_simd = m.train_step_into(&mut p_simd, &x, &y, 0.01, &mut scratch).unwrap();
        let ulp = max_ulp(&p, &p_simd);
        assert_eq!(ulp, 0, "{name}: SIMD drifted {ulp} ulp from the strict kernel");
        assert_eq!(l_new.to_bits(), l_simd.to_bits(), "{name}: SIMD loss diverged");

        let t_ref = bench_loop(2, iters, || {
            let (np, _) = reference::train_step(&m, &params, &x, &y, 0.01).unwrap();
            std::hint::black_box(&np);
        });
        float_mode::set_strict(true);
        let t_blocked = bench_loop(2, iters, || {
            p.copy_from_slice(&params);
            let loss = m.train_step_into(&mut p, &x, &y, 0.01, &mut scratch).unwrap();
            std::hint::black_box(loss);
        });
        float_mode::set_strict(false);
        let t_simd = bench_loop(2, iters, || {
            p.copy_from_slice(&params);
            let loss = m.train_step_into(&mut p, &x, &y, 0.01, &mut scratch).unwrap();
            std::hint::black_box(loss);
        });
        let ns_ref = mean(&t_ref) * 1e9;
        let ns_blocked = mean(&t_blocked) * 1e9;
        let ns_simd = mean(&t_simd) * 1e9;
        let speedup = ns_ref / ns_blocked;
        let simd_speedup = ns_blocked / ns_simd;
        println!(
            "  {name:<12} reference {ns_ref:>11.0} ns/step   blocked {ns_blocked:>11.0} \
             ns/step (x{speedup:.2})   simd {ns_simd:>11.0} ns/step (x{simd_speedup:.2}, 0 ulp)"
        );
        entries.push((
            name,
            Json::obj(vec![
                ("ns_per_step_reference", Json::num(ns_ref)),
                ("ns_per_step_blocked", Json::num(ns_blocked)),
                // the headline number: the default (SIMD) path
                ("ns_per_step", Json::num(ns_simd)),
                ("speedup", Json::num(speedup)),
                ("simd_speedup", Json::num(simd_speedup)),
                ("simd_max_ulp_vs_strict", Json::num(ulp as f64)),
            ]),
        ));
    }
    Json::obj(entries)
}

/// Wire plane: billed uplink bytes per round for each `--compress` mode
/// on the tiny preset, with the ratio against the dense format.
fn wire_plane(fast: bool) -> Json {
    println!("\n== wire plane: billed uplink bytes per round by --compress mode ==");
    let manifest = Manifest::host();
    let rounds = if fast { 3usize } else { 5 };
    let modes = [CompressMode::None, CompressMode::TopK(0.1), CompressMode::Int8];
    let mut entries = Vec::new();
    let mut dense_bytes = f64::NAN;
    for mode in modes {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = rounds;
        cfg.target_accuracy = None;
        cfg.compress = mode;
        let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
        let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
        let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
        let per_round = res.ledger.wire_bytes / rounds as f64;
        if mode.is_none() {
            dense_bytes = per_round;
        }
        let ratio = per_round / dense_bytes;
        println!(
            "  {:<10} {per_round:>12.0} bytes/round   x{ratio:.3} of dense   (acc {:.1}%)",
            mode.name(),
            res.final_accuracy * 100.0
        );
        entries.push(Json::obj(vec![
            ("mode", Json::str(&mode.name())),
            ("bytes_per_round", Json::num(per_round)),
            ("ratio_vs_dense", Json::num(ratio)),
        ]));
    }
    Json::Arr(entries)
}

/// Scatter-gather over a CPU-bound per-client job (parameter-vector math
/// shaped like one local round), isolating the engine's scaling from the
/// simulator.
fn engine_sweep_synthetic(fast: bool) {
    println!("\n== engine scatter-gather: workers vs wall-clock (synthetic per-client load) ==");
    let p = 44_426usize; // LeNet-5-sized flat parameter vector
    let tasks: Vec<u64> = (0..if fast { 16 } else { 48 }).collect();
    let base: Vec<f32> = (0..p).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut baseline: Option<f64> = None;
    let sweep: &[usize] = if fast { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    for &w in sweep {
        let engine = Engine::new(w);
        let timer = Timer::start();
        let sums = engine.run(&tasks, |_, &seed| {
            let mut v = base.clone();
            let mut rng = Rng::new(seed);
            for _ in 0..40 {
                let a = rng.uniform_f32() - 0.5;
                for x in v.iter_mut() {
                    *x = *x * 0.999 + a * 0.001;
                }
            }
            v.iter().map(|&x| x as f64).sum::<f64>()
        });
        std::hint::black_box(&sums);
        let secs = timer.elapsed_secs();
        let base_secs = *baseline.get_or_insert(secs);
        println!(
            "  workers {w:>2}: {:>9.1} ms   speedup x{:.2}",
            secs * 1e3,
            base_secs / secs
        );
    }
}

/// The real thing: `run_clustered` on the host backend, sweeping the
/// engine worker count. Same seed → identical metrics at every width;
/// only the wall-clock (and rounds/sec) changes.
fn engine_sweep_round_loop(fast: bool) -> Json {
    let (clients, rounds) = if fast { (24usize, 2usize) } else { (48, 3) };
    let sweep: &[usize] = if fast { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    println!(
        "\n== full round loop: workers vs wall-clock (host backend, {clients} clients, MNIST geometry) =="
    );
    let manifest = Manifest::host();
    let mut baseline: Option<f64> = None;
    let mut reference_time: Option<f64> = None;
    let mut rows = Vec::new();
    for &w in sweep {
        let mut cfg = ExperimentConfig::mnist();
        cfg.clients = clients;
        cfg.train_samples = clients * 128;
        cfg.test_samples = 256;
        cfg.rounds = rounds;
        cfg.eval_batches = 2;
        cfg.target_accuracy = None;
        cfg.workers = w;
        let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
        let timer = Timer::start();
        let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
        let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
        let secs = timer.elapsed_secs();
        // determinism cross-check while we are here
        match reference_time {
            None => reference_time = Some(res.ledger.time_s),
            Some(t) => assert_eq!(
                t, res.ledger.time_s,
                "worker count changed the simulated metrics!"
            ),
        }
        let base_secs = *baseline.get_or_insert(secs);
        let rps = rounds as f64 / secs;
        println!(
            "  workers {w:>2}: {:>9.1} ms wall   {rps:>6.2} rounds/s   speedup x{:.2}   (sim time {:.0} s, acc {:.1}%)",
            secs * 1e3,
            base_secs / secs,
            res.ledger.time_s,
            res.final_accuracy * 100.0
        );
        rows.push(Json::obj(vec![
            ("workers", Json::num(w as f64)),
            ("wall_ms", Json::num(secs * 1e3)),
            ("rounds_per_sec", Json::num(rps)),
        ]));
    }
    Json::obj(vec![
        ("clients", Json::num(clients as f64)),
        ("rounds", Json::num(rounds as f64)),
        ("sweep", Json::Arr(rows)),
    ])
}

/// Steady-state allocation audit: run the full FedHC round loop for R and
/// 2R rounds under identical seeds; the per-round delta isolates the
/// steady state from warm-up (pool fills, first-eval buffers, topology
/// build). Parameter-sized allocations per steady-state round must be
/// exactly zero — that is the invariant the recycled parameter pool and
/// the in-place kernels exist to provide, so it is asserted, not reported
/// as a soft threshold.
fn alloc_accounting(fast: bool) -> Json {
    println!("\n== steady-state allocation audit (counting allocator, tiny preset, 4 workers) ==");
    let manifest = Manifest::host();
    let (r1, r2) = if fast { (3usize, 6usize) } else { (4, 8) };
    let param_bytes = manifest.variant("tiny_mlp").unwrap().param_count * 4;
    let run = |rounds: usize, aggregation: AggregationMode, buffer: usize| -> (u64, u64) {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = rounds;
        cfg.workers = 4;
        cfg.eval_every = 1;
        cfg.aggregation = aggregation;
        cfg.buffer_size = buffer;
        // a dropout *rate* can never exceed 1.0: re-clustering (which
        // legitimately rebuilds models) stays out of the steady state
        cfg.recluster_threshold = 1.0;
        cfg.target_accuracy = None;
        let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
        let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
        PARAM_BYTES.store(rt.spec.param_count * 4, Ordering::Relaxed);
        let total0 = ALLOC_COUNT.load(Ordering::Relaxed);
        let param0 = PARAM_ALLOC_COUNT.load(Ordering::Relaxed);
        let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
        std::hint::black_box(res.final_accuracy);
        let total = ALLOC_COUNT.load(Ordering::Relaxed) - total0;
        let param = PARAM_ALLOC_COUNT.load(Ordering::Relaxed) - param0;
        PARAM_BYTES.store(usize::MAX, Ordering::Relaxed);
        (total, param)
    };
    let (t_a, p_a) = run(r1, AggregationMode::Sync, 0);
    let (t_b, p_b) = run(r2, AggregationMode::Sync, 0);
    let extra = (r2 - r1) as f64;
    let param_per_round = (p_b as f64 - p_a as f64) / extra;
    let total_per_round = (t_b as f64 - t_a as f64) / extra;
    println!("  {r1} rounds: {t_a} allocs ({p_a} parameter-sized ≥ {param_bytes} B)");
    println!("  {r2} rounds: {t_b} allocs ({p_b} parameter-sized ≥ {param_bytes} B)");
    println!(
        "  steady state: {total_per_round:.1} allocs/round, {param_per_round:.1} parameter-sized/round"
    );
    assert_eq!(
        p_b, p_a,
        "steady-state rounds must perform zero parameter-sized allocations"
    );
    // the buffered collection plane must keep the same invariant: parked
    // contributions recycle pool buffers, they never allocate fresh ones —
    // a goal of 2 forces real cross-round parking, the worst case
    let (_, bp_a) = run(r1, AggregationMode::Buffered, 2);
    let (_, bp_b) = run(r2, AggregationMode::Buffered, 2);
    let buffered_per_round = (bp_b as f64 - bp_a as f64) / extra;
    println!(
        "  buffered (goal 2): {bp_a} → {bp_b} parameter-sized allocs ({buffered_per_round:.1}/round)"
    );
    assert_eq!(
        bp_b, bp_a,
        "buffered steady-state rounds must perform zero parameter-sized allocations"
    );
    Json::obj(vec![
        ("param_bytes_threshold", Json::num(param_bytes as f64)),
        ("param_sized_per_round", Json::num(param_per_round)),
        ("total_per_round", Json::num(total_per_round)),
        ("buffered_param_sized_per_round", Json::num(buffered_per_round)),
    ])
}

fn bench_variant(manifest: &Manifest, name: &str, iters: usize) {
    let rt = match ModelRuntime::load(manifest, name) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping {name}: {e}");
            return;
        }
    };
    let spec = &rt.spec;
    let p = spec.param_count;
    let b = spec.batch;
    let d = spec.input_dim();
    let s = spec.chunk_steps;
    let mut rng = Rng::new(1);
    let params = manifest.init_params(spec).unwrap();
    let x: Vec<f32> = (0..b * d).map(|_| rng.uniform_f32()).collect();
    let y: Vec<f32> = (0..b).map(|_| rng.below(10) as f32).collect();
    let xs: Vec<f32> = (0..s * b * d).map(|_| rng.uniform_f32()).collect();
    let ys: Vec<f32> = (0..s * b).map(|_| rng.below(10) as f32).collect();
    let stack: Vec<Vec<f32>> = (0..spec.agg_slots)
        .map(|_| (0..p).map(|_| rng.uniform_f32()).collect())
        .collect();
    let rows: Vec<&[f32]> = stack.iter().map(|r| r.as_slice()).collect();
    let w = vec![1.0 / spec.agg_slots as f32; spec.agg_slots];

    println!("== {name} (P={p}, B={b}) ==");
    let t = bench_loop(2, iters, || {
        rt.train_step(&params, &x, &y, 0.01).unwrap();
    });
    println!("{}", bench_report(&format!("{name}/train_step"), &t));
    let t = bench_loop(2, iters, || {
        rt.train_chunk(&params, &xs, &ys, 0.01).unwrap();
    });
    println!(
        "{}  ({}x steps/call)",
        bench_report(&format!("{name}/train_chunk[{s}]"), &t),
        s
    );
    let t = bench_loop(2, iters, || {
        rt.eval_step(&params, &x, &y).unwrap();
    });
    println!("{}", bench_report(&format!("{name}/eval_step"), &t));
    let t = bench_loop(2, iters, || {
        rt.maml_step(&params, &x, &y, &x, &y, 1e-3, 1e-3).unwrap();
    });
    println!("{}", bench_report(&format!("{name}/maml_step"), &t));
    let t = bench_loop(2, iters, || {
        rt.aggregate(&rows, &w).unwrap();
    });
    println!(
        "{}",
        bench_report(&format!("{name}/aggregate[{}]", spec.agg_slots), &t)
    );
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");

    let kernels = kernel_before_after(fast);
    let wire = wire_plane(fast);
    engine_sweep_synthetic(fast);
    // wall-clock phase attribution over the real round loop: the scoped
    // timers are host-clock observers only, so the sweep's determinism
    // cross-check still passes with them enabled
    profile::enable();
    profile::reset();
    let round_loop = engine_sweep_round_loop(fast);
    let ns_per_phase = profile::to_json();
    println!("\n{}", profile::format_summary());
    let allocs = alloc_accounting(fast);

    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let manifest = Manifest::load(&dir).expect("artifacts manifest");
        println!();
        bench_variant(&manifest, "tiny_mlp", if fast { 10 } else { 30 });
        bench_variant(&manifest, "mnist_lenet", if fast { 5 } else { 15 });
        bench_variant(&manifest, "cifar_lenet", if fast { 3 } else { 10 });
    } else {
        eprintln!("\nno AOT artifacts under {dir:?}; skipping per-entry-point PJRT benches");
    }

    let json = Json::obj(vec![
        ("mode", Json::str(if fast { "fast" } else { "full" })),
        ("host_kernels", kernels),
        ("wire_plane", wire),
        ("round_loop", round_loop),
        ("ns_per_phase", ns_per_phase),
        ("allocs", allocs),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_runtime.json");
    std::fs::write(path, json.to_pretty() + "\n").expect("write BENCH_runtime.json");
    println!("\nwrote {path}");
}

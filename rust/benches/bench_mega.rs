//! Mega-constellation bench: the constellation plane at N ∈ {96, 1k, 5k}.
//!
//! Three question groups, emitted to `BENCH_mega.json`:
//!
//! 1. **Index build** — sphere-grid construction time per epoch.
//! 2. **Query speedups** — k-means nearest-centroid assignment,
//!    ground-visibility probing and LoS neighbor queries, brute force vs
//!    index-pruned, with bit-identity asserted on every comparison (the
//!    exactness guarantee is a correctness claim, so it panics the bench;
//!    the speedup numbers are reported, never thresholded — repo bench
//!    convention).
//! 3. **End-to-end rounds/sec** — the full FedHC round loop on the
//!    `mega-sparse` (1 000 clients) and `mega-dense` (5 000 clients)
//!    presets: spatial index on, bounded-memory pooled round path, event
//!    timeline. `--fast` still runs the complete 5 000-satellite
//!    configuration, just fewer rounds/iterations.
//!
//!     cargo bench --bench bench_mega [-- --fast]

use fedhc::clustering::kmeans::KMeans;
use fedhc::clustering::ps_select::{select_parameter_servers, select_parameter_servers_los};
use fedhc::config::ExperimentConfig;
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::fl::CompressMode;
use fedhc::network::{LinkModel, NetworkParams};
use fedhc::orbit::geo::default_ground_segment;
use fedhc::orbit::index::{assign_nearest_brute, los_neighbors_brute, SphereGrid};
use fedhc::orbit::propagate::Constellation;
use fedhc::orbit::visibility::{visible_sats, visible_sats_indexed};
use fedhc::orbit::walker::WalkerConstellation;
use fedhc::runtime::{Manifest, ModelRuntime};
use fedhc::util::json::Json;
use fedhc::util::profile;
use fedhc::util::stats::{bench_loop, mean, Timer};
use fedhc::util::Rng;

struct Tier {
    label: &'static str,
    walker: WalkerConstellation,
    k: usize,
}

fn tiers() -> Vec<Tier> {
    vec![
        Tier {
            label: "paper-96",
            walker: WalkerConstellation::paper_shell(8, 12),
            k: 3,
        },
        Tier {
            label: "mega-1k",
            walker: WalkerConstellation::mega_shell(40, 25),
            k: 10,
        },
        Tier {
            label: "mega-5k",
            walker: WalkerConstellation::mega_shell(40, 125),
            k: 40,
        },
    ]
}

fn geometry_suite(fast: bool) -> Json {
    println!("== constellation plane: index build + query speedups (bit-identity asserted) ==");
    let (warmup, iters) = if fast { (1, 8) } else { (2, 30) };
    let mut rows: Vec<Json> = Vec::new();
    for tier in tiers() {
        let c = Constellation::from_walker(&tier.walker);
        let n = c.len();
        let epoch = 1234.5;
        let snap = c.snapshot(epoch);
        let feats = snap.features_km();
        let bands = SphereGrid::auto_bands(n);
        let grid = SphereGrid::build(&feats, bands);

        // index build time per epoch
        let t_build = bench_loop(warmup, iters, || {
            std::hint::black_box(SphereGrid::build(&feats, bands));
        });

        // (a) k-means assignment: converged centroids, then the Eq. 13
        // step brute vs pruned — winners must match bit for bit, and the
        // full Lloyd runs must agree too
        let mut rng = Rng::new(7);
        let res = KMeans::new(tier.k).run(&feats, &mut rng).expect("kmeans");
        let mut rng_ix = Rng::new(7);
        let res_ix = KMeans::new(tier.k)
            .run_indexed(&feats, &mut rng_ix, Some(&grid))
            .expect("kmeans (indexed)");
        assert_eq!(
            res.assignment, res_ix.assignment,
            "{}: indexed k-means diverged from brute force",
            tier.label
        );
        let cents = &res.centroids;
        let mut a_brute = Vec::new();
        let mut a_index = Vec::new();
        assign_nearest_brute(&feats, cents, &mut a_brute);
        grid.assign_nearest(cents, &mut a_index);
        assert_eq!(a_brute, a_index, "{}: assignment step diverged", tier.label);
        let t_ab = bench_loop(warmup, iters, || {
            assign_nearest_brute(&feats, cents, &mut a_brute);
            std::hint::black_box(&a_brute);
        });
        let t_ai = bench_loop(warmup, iters, || {
            grid.assign_nearest(cents, &mut a_index);
            std::hint::black_box(&a_index);
        });

        // (b) ground-visibility probe
        let gs = &default_ground_segment()[0];
        let v_brute = visible_sats(gs, &c, epoch);
        let v_index = visible_sats_indexed(gs, &snap, &grid);
        assert_eq!(v_brute, v_index, "{}: visible set diverged", tier.label);
        let t_vb = bench_loop(warmup, iters, || {
            std::hint::black_box(visible_sats(gs, &c, epoch));
        });
        let t_vi = bench_loop(warmup, iters, || {
            std::hint::black_box(visible_sats_indexed(gs, &snap, &grid));
        });

        // (c) LoS neighbors within a 2 000 km ISL budget
        let range_m = 2_000e3;
        let probe = n / 2;
        let mut l_brute = Vec::new();
        let mut l_index = Vec::new();
        los_neighbors_brute(probe, range_m, &snap.positions, &mut l_brute);
        grid.los_neighbors(probe, range_m, &snap.positions, &mut l_index);
        assert_eq!(l_brute, l_index, "{}: LoS neighbors diverged", tier.label);
        let t_lb = bench_loop(warmup, iters, || {
            los_neighbors_brute(probe, range_m, &snap.positions, &mut l_brute);
            std::hint::black_box(&l_brute);
        });
        let t_li = bench_loop(warmup, iters, || {
            grid.los_neighbors(probe, range_m, &snap.positions, &mut l_index);
            std::hint::black_box(&l_index);
        });

        // PS selection: the classic tie-break vs the LoS-aware one (only
        // ISL-feasible peers count), the latter through the grid
        let link = LinkModel::new(NetworkParams::default());
        let (t_ps, t_ps_los) = if res.sizes().iter().all(|&s| s > 0) {
            let t_ps = bench_loop(warmup, iters.min(10), || {
                std::hint::black_box(select_parameter_servers(&res, &snap.positions, &link));
            });
            let t_ps_los = bench_loop(warmup, iters.min(10), || {
                std::hint::black_box(select_parameter_servers_los(
                    &res,
                    &snap.positions,
                    &link,
                    Some(&grid),
                    range_m,
                ));
            });
            (mean(&t_ps) * 1e3, mean(&t_ps_los) * 1e3)
        } else {
            // an empty cluster would trip ps_select's precondition;
            // -1 marks the skipped measurement in the JSON
            (-1.0, -1.0)
        };

        let assign_speedup = mean(&t_ab) / mean(&t_ai);
        let visible_speedup = mean(&t_vb) / mean(&t_vi);
        let los_speedup = mean(&t_lb) / mean(&t_li);
        println!(
            "  {:<9} n={n:>5} k={:>2} bands={bands:>2} cells={:>4}: build {:>8.3} ms | \
             assign x{assign_speedup:<5.2} visible x{visible_speedup:<5.2} los x{los_speedup:<5.2}",
            tier.label,
            tier.k,
            grid.cells(),
            mean(&t_build) * 1e3,
        );
        rows.push(Json::obj(vec![
            ("tier", Json::str(tier.label)),
            ("n", Json::num(n as f64)),
            ("k", Json::num(tier.k as f64)),
            ("bands", Json::num(bands as f64)),
            ("cells", Json::num(grid.cells() as f64)),
            ("index_build_ms", Json::num(mean(&t_build) * 1e3)),
            ("assign_brute_ms", Json::num(mean(&t_ab) * 1e3)),
            ("assign_indexed_ms", Json::num(mean(&t_ai) * 1e3)),
            ("assign_speedup", Json::num(assign_speedup)),
            ("visible_brute_ms", Json::num(mean(&t_vb) * 1e3)),
            ("visible_indexed_ms", Json::num(mean(&t_vi) * 1e3)),
            ("visible_speedup", Json::num(visible_speedup)),
            ("los_brute_ms", Json::num(mean(&t_lb) * 1e3)),
            ("los_indexed_ms", Json::num(mean(&t_li) * 1e3)),
            ("los_speedup", Json::num(los_speedup)),
            ("ps_select_ms", Json::num(t_ps)),
            ("ps_select_los_ms", Json::num(t_ps_los)),
        ]));
    }
    Json::Arr(rows)
}

fn end_to_end(fast: bool) -> Json {
    let manifest = Manifest::host();
    let rounds = if fast { 2 } else { 5 };
    println!("\n== end-to-end FedHC rounds (pooled round path, index on, event timeline) ==");
    let mut rows: Vec<Json> = Vec::new();
    for preset in ["mega-sparse", "mega-dense"] {
        let mut cfg = ExperimentConfig::preset(preset).expect("mega preset");
        cfg.rounds = rounds;
        let rt = ModelRuntime::load(&manifest, cfg.variant()).expect("runtime");
        let timer = Timer::start();
        let mut trial = Trial::new(cfg.clone(), &manifest, &rt).expect("trial");
        let setup_ms = timer.elapsed_ms();
        let timer = Timer::start();
        let res = run_clustered(&mut trial, Strategy::fedhc()).expect("run");
        let wall = timer.elapsed_secs();
        let rps = rounds as f64 / wall;
        // structural claims, not perf thresholds: the run completed its
        // budget, recorded evaluations, simulated real time/energy, and
        // the pooled mode left no resident per-client parameters
        assert!(!res.ledger.records.is_empty(), "{preset}: no eval records");
        assert!(res.ledger.time_s > 0.0 && res.ledger.energy_j > 0.0);
        assert!(
            trial.clients.iter().all(|c| c.params.is_empty()),
            "{preset}: pooled mode left resident client parameters"
        );
        // wire plane: the same run under `--compress topk:0.1` must bill
        // ≤ 15 % of the dense uplink bytes per round — a wire-format
        // property (bit-packed indices), deterministic, so it is asserted
        let bytes_per_round = res.ledger.wire_bytes / rounds as f64;
        let mut topk_cfg = cfg.clone();
        topk_cfg.compress = CompressMode::TopK(0.1);
        let mut topk_trial = Trial::new(topk_cfg, &manifest, &rt).expect("trial (topk)");
        let topk = run_clustered(&mut topk_trial, Strategy::fedhc()).expect("run (topk)");
        let topk_bytes_per_round = topk.ledger.wire_bytes / rounds as f64;
        let topk_ratio = topk_bytes_per_round / bytes_per_round;
        assert!(
            topk_ratio <= 0.15,
            "{preset}: topk:0.1 billed {topk_ratio} of dense bytes (> 15 %)"
        );
        println!(
            "  {preset:<12} {:>5} clients K={:<3} setup {:>8.0} ms | {rounds} rounds in {:>8.1} ms \
             ({rps:.2} rounds/s, sim {:.0} s, acc {:.1}%)",
            cfg.clients,
            cfg.clusters,
            setup_ms,
            wall * 1e3,
            res.ledger.time_s,
            res.final_accuracy * 100.0,
        );
        println!(
            "  {preset:<12} wire: dense {bytes_per_round:>12.0} B/round, \
             topk:0.1 {topk_bytes_per_round:>11.0} B/round (x{topk_ratio:.3})"
        );
        rows.push(Json::obj(vec![
            ("preset", Json::str(preset)),
            ("clients", Json::num(cfg.clients as f64)),
            ("clusters", Json::num(cfg.clusters as f64)),
            ("rounds", Json::num(rounds as f64)),
            ("setup_ms", Json::num(setup_ms)),
            ("wall_ms", Json::num(wall * 1e3)),
            ("rounds_per_sec", Json::num(rps)),
            ("sim_time_s", Json::num(res.ledger.time_s)),
            ("best_accuracy", Json::num(res.final_accuracy)),
            ("bytes_per_round", Json::num(bytes_per_round)),
            ("topk_bytes_per_round", Json::num(topk_bytes_per_round)),
            ("topk_ratio_vs_dense", Json::num(topk_ratio)),
        ]));
    }
    Json::Arr(rows)
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let geometry = geometry_suite(fast);
    // wall-clock phase attribution over the mega round loops (host clock
    // only; the structural assertions inside end_to_end are unaffected)
    profile::enable();
    profile::reset();
    let e2e = end_to_end(fast);
    let ns_per_phase = profile::to_json();
    println!("\n{}", profile::format_summary());
    let json = Json::obj(vec![
        ("mode", Json::str(if fast { "fast" } else { "full" })),
        ("geometry", geometry),
        ("end_to_end", e2e),
        ("ns_per_phase", ns_per_phase),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_mega.json");
    std::fs::write(path, json.to_pretty() + "\n").expect("write BENCH_mega.json");
    println!("\nwrote {path}");
}

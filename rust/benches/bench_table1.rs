//! Table I bench target: regenerates the paper's headline table (time +
//! energy to target accuracy, 4 methods × K ∈ {3,4,5}) on the tiny preset
//! so `cargo bench` completes in minutes. The paper-scale MNIST/CIFAR
//! versions are `cargo run --release --example table1_repro mnist|cifar10`
//! (results recorded in EXPERIMENTS.md).
//!
//!     cargo bench --bench bench_table1

use fedhc::baselines::run_cfedavg;
use fedhc::config::ExperimentConfig;
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::metrics::report::{format_table1, TimeEnergy};
use fedhc::runtime::{Manifest, ModelRuntime};

const METHODS: &[&str] = &["C-FedAvg", "H-BASE", "FedCE", "FedHC"];

fn cell(cfg: ExperimentConfig, method: &'static str) -> TimeEnergy {
    let manifest = Manifest::load_or_host(&Manifest::default_dir()).unwrap();
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    let res = match method {
        "C-FedAvg" => run_cfedavg(&mut trial).unwrap(),
        "H-BASE" => run_clustered(&mut trial, Strategy::hbase()).unwrap(),
        "FedCE" => run_clustered(&mut trial, Strategy::fedce()).unwrap(),
        "FedHC" => run_clustered(&mut trial, Strategy::fedhc()).unwrap(),
        _ => unreachable!(),
    };
    match res.converged_at {
        Some((_, t, e)) => TimeEnergy { time_s: t, energy_j: e, converged: true },
        None => TimeEnergy {
            time_s: res.ledger.time_s,
            energy_j: res.ledger.energy_j,
            converged: false,
        },
    }
}

fn main() {
    let mut base = ExperimentConfig::tiny();
    base.target_accuracy = Some(0.6);
    base.rounds = 40;
    let ks = [3usize, 4, 5];

    let mut handles = Vec::new();
    for &method in METHODS {
        for &k in &ks {
            let mut cfg = base.clone();
            cfg.clusters = k;
            handles.push((method, k, std::thread::spawn(move || cell(cfg, method))));
        }
    }
    let mut cells: std::collections::BTreeMap<(&str, usize), TimeEnergy> = Default::default();
    for (m, k, h) in handles {
        cells.insert((m, k), h.join().expect("worker panicked"));
    }
    let rows: Vec<(&str, Vec<TimeEnergy>)> = METHODS
        .iter()
        .map(|&m| (m, ks.iter().map(|&k| cells[&(m, k)]).collect()))
        .collect();
    println!(
        "{}",
        format_table1("tiny (synthetic)", base.target_accuracy.unwrap(), &ks, &rows)
    );

    // the paper's qualitative ordering must hold on every K
    for &k in &ks {
        let t_fedhc = cells[&("FedHC", k)].time_s;
        let t_central = cells[&("C-FedAvg", k)].time_s;
        assert!(
            t_fedhc < t_central,
            "K={k}: FedHC time {t_fedhc} not below C-FedAvg {t_central}"
        );
    }
    println!("ordering check: FedHC beats C-FedAvg on time for all K ✓");
}

//! Routing-plane bench: multi-hop ISL trees vs the one-hop teleport.
//!
//! Two question groups, emitted to `BENCH_routing.json`:
//!
//! 1. **Tree construction** — per-cluster BFS route-tree build time on the
//!    paper shell (and the 5 000-satellite mega shell in full mode), brute
//!    oracle vs sphere-grid pruned, with bit-identity asserted on every
//!    comparison (the exactness guarantee is a correctness claim, so it
//!    panics the bench; timings are reported, never thresholded).
//! 2. **End-to-end divergence** — FedHC under `--routing direct`, `isl`
//!    and `isl:ring` on a geometry where routing genuinely engages: the
//!    tiny shell as one cluster at 9 000 km ISL range (each orbital plane
//!    becomes a 6-ring, paths reach three hops), plus the `mega-dense`
//!    preset at its default 2 000 km range in full mode. The structural
//!    claims: `isl` must traverse hops and fold partial aggregates at
//!    relays, must never move **more** uplink bytes than direct (the
//!    in-route aggregation payoff: each tree edge carries exactly one
//!    pooled upload), and must diverge from the teleport's clock —
//!    while `direct` stays the committed baseline bit for bit.
//!
//!     cargo bench --bench bench_routing [-- --fast]

use fedhc::config::{ExperimentConfig, RoutingMode};
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::network::build_route_tree;
use fedhc::orbit::index::SphereGrid;
use fedhc::orbit::propagate::Constellation;
use fedhc::orbit::walker::WalkerConstellation;
use fedhc::runtime::{Manifest, ModelRuntime};
use fedhc::util::json::Json;
use fedhc::util::stats::{bench_loop, mean, Timer};

/// Route-tree build microbench: one "cluster" spanning most of the shell
/// (every third satellite dropped so `nodes` exercises the filter path),
/// brute vs indexed, bit-identity asserted.
fn tree_suite(fast: bool) -> Json {
    println!("== route-tree construction: brute vs sphere-grid (bit-identity asserted) ==");
    let (warmup, iters) = if fast { (1, 8) } else { (2, 30) };
    let tiers: Vec<(&str, WalkerConstellation, f64)> = if fast {
        vec![("paper-96", WalkerConstellation::paper_shell(8, 12), 4500e3)]
    } else {
        vec![
            ("paper-96", WalkerConstellation::paper_shell(8, 12), 4500e3),
            ("mega-5k", WalkerConstellation::mega_shell(40, 125), 2000e3),
        ]
    };
    let mut rows: Vec<Json> = Vec::new();
    for (label, walker, range_m) in tiers {
        let c = Constellation::from_walker(&walker);
        let snap = c.snapshot(1234.5);
        let feats = snap.features_km();
        let grid = SphereGrid::build(&feats, SphereGrid::auto_bands(c.len()));
        let nodes: Vec<usize> = (0..c.len()).filter(|i| i % 3 != 1).collect();
        let mut scratch = Vec::new();
        let brute = build_route_tree(
            &nodes, 0, range_m, &snap.positions, None, &|_| false, &mut scratch,
        );
        let indexed = build_route_tree(
            &nodes, 0, range_m, &snap.positions, Some(&grid), &|_| false, &mut scratch,
        );
        assert_eq!(brute, indexed, "{label}: grid-pruned tree drifted from the brute oracle");
        assert!(brute.max_hops() > 1, "{label}: shell must be multi-hop at {range_m} m");
        let t_brute = bench_loop(warmup, iters, || {
            std::hint::black_box(build_route_tree(
                &nodes, 0, range_m, &snap.positions, None, &|_| false, &mut scratch,
            ));
        });
        let t_index = bench_loop(warmup, iters, || {
            std::hint::black_box(build_route_tree(
                &nodes, 0, range_m, &snap.positions, Some(&grid), &|_| false, &mut scratch,
            ));
        });
        let speedup = mean(&t_brute) / mean(&t_index);
        println!(
            "  {label:<9} n={:>5} range {:>5.0} km: max_hops {:>2} | brute {:>8.3} ms, \
             indexed {:>8.3} ms (x{speedup:.2})",
            nodes.len(),
            range_m / 1e3,
            brute.max_hops(),
            mean(&t_brute) * 1e3,
            mean(&t_index) * 1e3,
        );
        rows.push(Json::obj(vec![
            ("tier", Json::str(label)),
            ("n", Json::num(nodes.len() as f64)),
            ("range_km", Json::num(range_m / 1e3)),
            ("max_hops", Json::num(brute.max_hops() as f64)),
            ("build_brute_ms", Json::num(mean(&t_brute) * 1e3)),
            ("build_indexed_ms", Json::num(mean(&t_index) * 1e3)),
            ("build_speedup", Json::num(speedup)),
        ]));
    }
    Json::Arr(rows)
}

/// The divergence geometries. `tiny-1k9000`: the whole tiny shell as one
/// cluster at 9 000 km range — each orbital plane is a 6-ring from the
/// PS's point of view, so store-and-forward paths reach three hops and
/// every round folds partial aggregates at relays. `mega-dense`: the
/// 5 000-satellite preset at its default 2 000 km range, where k-means
/// clusters span more than one hop of the dense ISL mesh.
fn e2e_configs(fast: bool) -> Vec<(&'static str, ExperimentConfig)> {
    let mut tiny = ExperimentConfig::tiny();
    tiny.rounds = 5;
    tiny.target_accuracy = None;
    tiny.clusters = 1;
    tiny.isl_range_km = 9000.0;
    let mut out = vec![("tiny-1x9000km", tiny)];
    if !fast {
        let mut mega = ExperimentConfig::preset("mega-dense").expect("mega preset");
        mega.rounds = 3;
        out.push(("mega-dense", mega));
    }
    out
}

fn e2e_suite(fast: bool) -> Json {
    let manifest = Manifest::host();
    println!("\n== end-to-end: direct teleport vs multi-hop isl vs ring all-reduce ==");
    let mut rows: Vec<Json> = Vec::new();
    for (label, base) in e2e_configs(fast) {
        let rt = ModelRuntime::load(&manifest, base.variant()).expect("runtime");
        let rounds = base.rounds as f64;
        let mut direct_bits: Option<(u64, u64, f64)> = None;
        for routing in [RoutingMode::Direct, RoutingMode::Isl, RoutingMode::Ring] {
            let mut cfg = base.clone();
            cfg.routing = routing;
            let timer = Timer::start();
            let mut trial = Trial::new(cfg, &manifest, &rt).expect("trial");
            let res = run_clustered(&mut trial, Strategy::fedhc()).expect("run");
            let wall_ms = timer.elapsed_ms();
            let l = &res.ledger;
            let hops_per_round = l.route_hops as f64 / rounds;
            let bytes_per_round = l.wire_bytes / rounds;
            // structural claims (panics, never perf thresholds)
            match routing {
                RoutingMode::Direct => {
                    assert_eq!(l.route_hops, 0, "{label}: direct must not touch the ISL plane");
                    assert_eq!(l.relay_merges, 0, "{label}: direct must not merge in-route");
                    direct_bits =
                        Some((l.time_s.to_bits(), l.energy_j.to_bits(), bytes_per_round));
                }
                RoutingMode::Isl => {
                    let (t_bits, e_bits, direct_bytes) =
                        direct_bits.expect("direct runs first");
                    assert!(l.route_hops > 0, "{label}: isl must traverse ISL hops");
                    assert!(l.relay_merges > 0, "{label}: isl must fold partial aggregates");
                    assert!(
                        l.time_s.to_bits() != t_bits || l.energy_j.to_bits() != e_bits,
                        "{label}: multi-hop isl must diverge from the one-hop teleport"
                    );
                    assert!(
                        bytes_per_round <= direct_bytes,
                        "{label}: in-route aggregation must never move more uplink \
                         bytes than the teleport ({bytes_per_round} vs {direct_bytes})"
                    );
                }
                RoutingMode::Ring => {
                    assert!(l.route_hops > 0, "{label}: ring must bill its 2(k-1) steps");
                    assert!(l.relay_merges > 0, "{label}: ring must fold chunk reductions");
                }
            }
            println!(
                "  {label:<13} {:<6} wall {:>8.1} ms | sim {:>9.0} s, {:>12.0} J, acc {:>5.1}% | \
                 {:>7.1} hops/round, {:>5} merges, {:>12.0} B/round",
                routing.name(),
                wall_ms,
                l.time_s,
                l.energy_j,
                res.final_accuracy * 100.0,
                hops_per_round,
                l.relay_merges,
                bytes_per_round,
            );
            rows.push(Json::obj(vec![
                ("config", Json::str(label)),
                ("routing", Json::str(routing.name())),
                ("rounds", Json::num(rounds)),
                ("wall_ms", Json::num(wall_ms)),
                ("sim_time_s", Json::num(l.time_s)),
                ("energy_j", Json::num(l.energy_j)),
                ("best_accuracy", Json::num(res.final_accuracy)),
                ("hops_per_round", Json::num(hops_per_round)),
                ("relay_merges", Json::num(l.relay_merges as f64)),
                ("bytes_per_round", Json::num(bytes_per_round)),
            ]));
        }
    }
    Json::Arr(rows)
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let trees = tree_suite(fast);
    let rounds = e2e_suite(fast);
    let json = Json::obj(vec![
        ("mode", Json::str(if fast { "fast" } else { "full" })),
        ("trees", trees),
        ("rounds", rounds),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_routing.json");
    std::fs::write(path, json.to_pretty() + "\n").expect("write BENCH_routing.json");
    println!("\nwrote {path}");
}

//! Minimal, dependency-free drop-in for the subset of the `anyhow` crate
//! this workspace uses. The build image has no crates.io registry, so the
//! real `anyhow` cannot be fetched; this vendored stand-in provides the
//! same surface with the same semantics:
//!
//! * [`Error`] — an opaque, context-carrying error. Converts from any
//!   `std::error::Error + Send + Sync + 'static` via `?`.
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`anyhow!`] / [`bail!`] — ad-hoc error construction macros.
//!
//! `Display` prints the outermost message; the alternate form (`{:#}`)
//! prints the whole cause chain separated by `: `, matching what callers
//! such as the `fedhc` binary's top-level error handler expect.

use std::fmt;

/// Opaque error: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            cause: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn wrap<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            cause: Some(Box::new(self)),
        }
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut items = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            items.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        items.into_iter()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.cause.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.cause.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // flatten the std source chain into our cause chain
        let mut msgs = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut cause = None;
        for m in msgs.into_iter().rev() {
            cause = Some(Box::new(Error { msg: m, cause }));
        }
        Error {
            msg: e.to_string(),
            cause,
        }
    }
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T>: Sized {
    /// Attach a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Attach a lazily-built context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert!(format!("{e:#}").contains("loading config: "));
        assert!(format!("{e:#}").contains("missing"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("no {}", "value")).unwrap_err();
        assert_eq!(e.to_string(), "no value");
        assert_eq!(Some(3u32).context("fine").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 4;
        let b = anyhow!("value {n} and {}", 5);
        assert_eq!(b.to_string(), "value 4 and 5");
        let s = String::from("owned");
        let c = anyhow!(s);
        assert_eq!(c.to_string(), "owned");
        fn bails() -> Result<()> {
            bail!("stop {}", 9)
        }
        assert_eq!(bails().unwrap_err().to_string(), "stop 9");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}

//! API-shape stub of the `xla` PJRT bindings.
//!
//! The offline build image carries no XLA shared library, so this crate
//! provides the exact API surface `fedhc::runtime::executor` compiles
//! against — clients, HLO protos, literals, executables — with honest
//! runtime behaviour: literal plumbing works, but compiling or executing
//! an HLO module returns a clear error telling the operator to either
//! install the real XLA-backed crate (swap this path dependency) or use
//! the built-in pure-Rust host backend, which is the default whenever no
//! AOT artifacts are present.
//!
//! Everything here is plain data and therefore `Send + Sync`, which the
//! parallel round engine relies on.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type (implements `std::error::Error` so it converts into
/// `anyhow::Error` via `?`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the real XLA/PJRT runtime; this build vendors an API stub. \
         Use the built-in host backend (the default without artifacts) or point the \
         workspace `xla` path dependency at an XLA-backed crate."
    )))
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// CPU client. Succeeds so that diagnostics (platform, device count)
    /// work; compilation is where the stub reports itself.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling an HLO computation")
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    /// Read an HLO text file. I/O works; only execution is stubbed.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("cannot read {path}: {e}")))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Loaded (compiled) executable. Never actually constructed by the stub,
/// but the type must exist for the executor to compile.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing a compiled module")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("transferring a device buffer")
    }
}

/// Element types a literal can be read back as.
pub trait NativeType: Sized {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

/// Host literal: flat f32 storage plus a shape.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    /// Reshape without changing element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let expect: i64 = dims.iter().product();
        if expect < 0 || expect as usize != self.data.len() {
            return Err(Error(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Shape of this literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Decompose a tuple literal. Stub literals are never tuples (they only
    /// ever come from [`Literal::vec1`]), so this reports unavailability.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("decomposing a result tuple")
    }

    /// Read the flat contents back.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn client_reports_stub_platform() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        assert_eq!(c.device_count(), 1);
        let comp = XlaComputation::from_proto(&HloModuleProto {
            _text: String::new(),
        });
        assert!(c.compile(&comp).is_err());
    }

    #[test]
    fn types_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<PjRtClient>();
        assert_send_sync::<PjRtLoadedExecutable>();
        assert_send_sync::<Literal>();
    }
}

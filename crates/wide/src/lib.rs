//! Vendored minimal `wide`-style SIMD vector for the offline image: an
//! 8-lane `f32` value type with the arithmetic the FedHC host kernels
//! need, plus runtime AVX2 detection for the dispatch in
//! `runtime::host_model`.
//!
//! The type is deliberately *portable*: it is a `#[repr(C, align(32))]`
//! array of eight lanes with element-wise `Add`/`Sub`/`Mul`. Every method
//! is `#[inline(always)]`, so when the ops are called from a
//! `#[target_feature(enable = "avx2")]` function the compiler lowers each
//! one to a single 256-bit vector instruction; called from ordinary code
//! they autovectorise to whatever the baseline target supports. Lane
//! arithmetic is exact IEEE-754 single precision either way — there is no
//! FMA contraction and no reassociation inside a lane, which is what lets
//! the host kernels keep their bit-exactness contract while vectorising.

#![forbid(unsafe_code)]

/// Eight `f32` lanes, element-wise arithmetic.
#[allow(non_camel_case_types)]
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[repr(C, align(32))]
pub struct f32x8 {
    lanes: [f32; 8],
}

/// Lane count of [`f32x8`].
pub const LANES: usize = 8;

impl f32x8 {
    /// All lanes set to `v`.
    #[inline(always)]
    pub fn splat(v: f32) -> f32x8 {
        f32x8 { lanes: [v; 8] }
    }

    /// Load the first eight elements of `src` (which must hold at least
    /// eight).
    #[inline(always)]
    pub fn from_slice(src: &[f32]) -> f32x8 {
        let mut lanes = [0.0f32; 8];
        lanes.copy_from_slice(&src[..8]);
        f32x8 { lanes }
    }

    /// Store the lanes into the first eight elements of `dst` (which must
    /// hold at least eight).
    #[inline(always)]
    pub fn write_to_slice(self, dst: &mut [f32]) {
        dst[..8].copy_from_slice(&self.lanes);
    }

    /// The lanes as a plain array.
    #[inline(always)]
    pub fn to_array(self) -> [f32; 8] {
        self.lanes
    }
}

impl std::ops::Add for f32x8 {
    type Output = f32x8;

    #[inline(always)]
    fn add(self, rhs: f32x8) -> f32x8 {
        let mut lanes = [0.0f32; 8];
        for i in 0..8 {
            lanes[i] = self.lanes[i] + rhs.lanes[i];
        }
        f32x8 { lanes }
    }
}

impl std::ops::Sub for f32x8 {
    type Output = f32x8;

    #[inline(always)]
    fn sub(self, rhs: f32x8) -> f32x8 {
        let mut lanes = [0.0f32; 8];
        for i in 0..8 {
            lanes[i] = self.lanes[i] - rhs.lanes[i];
        }
        f32x8 { lanes }
    }
}

impl std::ops::Mul for f32x8 {
    type Output = f32x8;

    #[inline(always)]
    fn mul(self, rhs: f32x8) -> f32x8 {
        let mut lanes = [0.0f32; 8];
        for i in 0..8 {
            lanes[i] = self.lanes[i] * rhs.lanes[i];
        }
        f32x8 { lanes }
    }
}

/// Whether the running CPU supports AVX2 (always `false` off x86-64).
/// Detection is cached by the standard library, so calling this on a hot
/// path costs one relaxed atomic load.
#[cfg(target_arch = "x86_64")]
pub fn have_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// Whether the running CPU supports AVX2 (always `false` off x86-64).
#[cfg(not(target_arch = "x86_64"))]
pub fn have_avx2() -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_arithmetic_is_element_wise() {
        let a = f32x8::from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let b = f32x8::splat(0.5);
        assert_eq!((a * b).to_array(), [0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0]);
        assert_eq!((a + b).to_array()[0], 1.5);
        assert_eq!((a - b).to_array()[7], 7.5);
    }

    #[test]
    fn lane_ops_are_exact_ieee_singles() {
        // no FMA, no reassociation: each lane must equal the scalar op
        let xs = [0.1f32, -2.5e-7, 3.9e8, -0.0, 1.0e-38, 7.7, -123.456, 42.0];
        let ys = [9.3f32, 1.5e-3, -2.0e8, 0.0, 3.0e-38, -0.1, 654.321, -42.0];
        let a = f32x8::from_slice(&xs);
        let b = f32x8::from_slice(&ys);
        let sum = (a + b).to_array();
        let prod = (a * b).to_array();
        let diff = (a - b).to_array();
        for i in 0..8 {
            assert_eq!(sum[i].to_bits(), (xs[i] + ys[i]).to_bits());
            assert_eq!(prod[i].to_bits(), (xs[i] * ys[i]).to_bits());
            assert_eq!(diff[i].to_bits(), (xs[i] - ys[i]).to_bits());
        }
    }

    #[test]
    fn roundtrip_through_slices() {
        let src = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let v = f32x8::from_slice(&src);
        let mut dst = [0.0f32; 9];
        v.write_to_slice(&mut dst);
        assert_eq!(&dst[..8], &src[..8]);
        assert_eq!(dst[8], 0.0, "store must touch exactly eight lanes");
    }
}

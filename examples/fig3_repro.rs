//! Fig. 3 reproduction: accuracy vs training round for the four methods
//! under K ∈ {3,4,5}, fixed round budget (no early stop).
//!
//!     cargo run --release --example fig3_repro [tiny|mnist|cifar10] [rounds]
//!
//! Each (method, K) series runs in its own thread; the series are printed
//! as aligned tables and written to results/ as CSV for plotting.

use fedhc::baselines::run_cfedavg;
use fedhc::config::ExperimentConfig;
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::metrics::recorder::write_series;
use fedhc::metrics::report::format_fig3;
use fedhc::metrics::Ledger;
use fedhc::runtime::{Manifest, ModelRuntime};
use std::path::Path;

const METHODS: &[&str] = &["C-FedAvg", "H-BASE", "FedCE", "FedHC"];

fn run_series(cfg: ExperimentConfig, method: &'static str) -> anyhow::Result<Ledger> {
    let manifest = Manifest::load_or_host(&Manifest::default_dir())?;
    let rt = ModelRuntime::load(&manifest, cfg.variant())?;
    let mut trial = Trial::new(cfg, &manifest, &rt)?;
    let res = match method {
        "C-FedAvg" => run_cfedavg(&mut trial)?,
        "H-BASE" => run_clustered(&mut trial, Strategy::hbase())?,
        "FedCE" => run_clustered(&mut trial, Strategy::fedce())?,
        "FedHC" => run_clustered(&mut trial, Strategy::fedhc())?,
        _ => unreachable!(),
    };
    Ok(res.ledger)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(|s| s.as_str()).unwrap_or("tiny");
    let mut base = ExperimentConfig::preset(preset).expect("unknown preset");
    base.target_accuracy = None;
    if let Some(r) = args.get(1).and_then(|s| s.parse().ok()) {
        base.rounds = r;
    } else if preset == "tiny" {
        base.rounds = 20;
    } else {
        // single-core-image scale (see table1_repro)
        base.clients = 16;
        base.train_samples = 4096;
        base.test_samples = 256;
        base.rounds = 20;
        base.eval_batches = 2;
        base.lr = 0.15;
        base.dirichlet_alpha = 1.0;
    }

    for k in [3usize, 4, 5] {
        eprintln!("fig3: K={k} ...");
        let mut handles = Vec::new();
        for &method in METHODS {
            let mut cfg = base.clone();
            cfg.clusters = k;
            handles.push((method, std::thread::spawn(move || run_series(cfg, method))));
        }
        let mut ledgers = Vec::new();
        for (method, h) in handles {
            ledgers.push((method, h.join().expect("worker panicked")?));
        }
        let series: Vec<(&str, &Ledger)> = ledgers.iter().map(|(n, l)| (*n, l)).collect();
        let every = (base.rounds / 10).max(1);
        println!("{}", format_fig3(base.dataset.name(), k, &series, every));
        for (name, ledger) in &ledgers {
            let stem = format!(
                "fig3_{}_{}_k{k}",
                name.to_lowercase().replace('-', ""),
                base.dataset.name()
            );
            write_series(ledger, Path::new("results"), &stem)?;
        }
    }
    eprintln!("series written under results/");
    Ok(())
}

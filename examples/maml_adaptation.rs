//! Ablation: the meta-learning-driven re-clustering algorithm (§III-C).
//!
//! Runs FedHC with and without the MAML warm start under aggressive churn
//! (high outage probability + low re-cluster threshold) and compares the
//! accuracy trajectories — isolating the contribution the paper credits
//! for its convergence speedup.
//!
//!     cargo run --release --example maml_adaptation

use anyhow::Result;
use fedhc::config::ExperimentConfig;
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::runtime::{Manifest, ModelRuntime};

fn main() -> Result<()> {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 24;
    cfg.outage_prob = 0.20; // aggressive churn
    cfg.recluster_threshold = 0.15;
    cfg.target_accuracy = None;

    let manifest = Manifest::load_or_host(&Manifest::default_dir())?;
    let rt = ModelRuntime::load(&manifest, cfg.variant())?;

    println!(
        "churn stress test: outage={:.0}%, Z={}, {} rounds\n",
        cfg.outage_prob * 100.0,
        cfg.recluster_threshold,
        cfg.rounds
    );

    let mut results = Vec::new();
    for strat in [Strategy::fedhc(), Strategy::fedhc_no_maml()] {
        let mut trial = Trial::new(cfg.clone(), &manifest, &rt)?;
        let res = run_clustered(&mut trial, strat)?;
        println!(
            "{:<14} best acc {:>6.2}%  reclusters {:>2}  maml adapts {:>3}",
            res.name,
            res.final_accuracy * 100.0,
            res.ledger.reclusters,
            res.ledger.maml_adaptations
        );
        results.push(res);
    }

    println!("\nround   with-MAML   without-MAML");
    let (with, without) = (&results[0].ledger, &results[1].ledger);
    for (a, b) in with.records.iter().zip(&without.records) {
        println!(
            "{:>5} {:>10.2}% {:>13.2}%{}",
            a.round,
            a.accuracy * 100.0,
            b.accuracy * 100.0,
            if a.reclustered || b.reclustered { "   <- re-cluster" } else { "" }
        );
    }

    let gain = results[0].final_accuracy - results[1].final_accuracy;
    println!(
        "\nMAML warm-start accuracy gain under churn: {:+.2} pp",
        gain * 100.0
    );
    Ok(())
}

//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Exercises every layer on the paper's real workload: a LeNet-5 model
//! (AOT: JAX fwd/bwd over Pallas dense/SGD/aggregation kernels → HLO →
//! PJRT) trained by the full FedHC stack — Walker constellation, k-means
//! PS selection, two-stage aggregation, churn-driven MAML re-clustering —
//! on MNIST-geometry data for a few hundred rounds, logging the loss
//! curve and time/energy ledger.
//!
//!     cargo run --release --example end_to_end_train [rounds] [clients]
//!
//! Defaults: 200 rounds, 48 clients (≈25 min wall on this CPU image).
//! The curve is written to results/e2e_mnist.csv.

use anyhow::Result;
use fedhc::config::ExperimentConfig;
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::metrics::recorder::write_series;
use fedhc::runtime::{Manifest, ModelRuntime};
use std::path::Path;
use std::time::Instant;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rounds: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let clients: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(48);

    let mut cfg = ExperimentConfig::mnist();
    cfg.rounds = rounds;
    cfg.clients = clients;
    cfg.train_samples = clients * 128;
    cfg.test_samples = 512;
    cfg.eval_batches = 4;
    cfg.lr = 0.1;
    cfg.target_accuracy = None; // run the full budget, log the whole curve

    let manifest = Manifest::load_or_host(&Manifest::default_dir())?;
    let rt = ModelRuntime::load(&manifest, cfg.variant())?;
    println!(
        "e2e: LeNet-5 (P={}) × {} clients × {} rounds, K={}, platform={}",
        rt.spec.param_count,
        cfg.clients,
        cfg.rounds,
        cfg.clusters,
        rt.platform()
    );

    let wall = Instant::now();
    let mut trial = Trial::new(cfg, &manifest, &rt)?;
    let res = run_clustered(&mut trial, Strategy::fedhc())?;
    let wall_s = wall.elapsed().as_secs_f64();

    println!("\nloss curve (every 10th eval):");
    println!("round   sim-time(s)   energy(J)   loss     accuracy");
    for r in res.ledger.records.iter().step_by(10) {
        println!(
            "{:>5} {:>13.1} {:>11.1} {:>8.4} {:>9.2}%",
            r.round, r.time_s, r.energy_j, r.loss, r.accuracy * 100.0
        );
    }
    if let Some(last) = res.ledger.records.last() {
        println!(
            "{:>5} {:>13.1} {:>11.1} {:>8.4} {:>9.2}%  (final)",
            last.round, last.time_s, last.energy_j, last.loss, last.accuracy * 100.0
        );
    }
    println!(
        "\nbest accuracy {:.2}% | sim time {:.0} s | energy {:.0} J | \
         {} reclusters | {} MAML adapts | wall {:.0} s | {} PJRT calls",
        res.final_accuracy * 100.0,
        res.ledger.time_s,
        res.ledger.energy_j,
        res.ledger.reclusters,
        res.ledger.maml_adaptations,
        wall_s,
        rt.call_count()
    );
    write_series(&res.ledger, Path::new("results"), "e2e_mnist")?;
    println!("curve written to results/e2e_mnist.csv");
    Ok(())
}

//! Quickstart: run FedHC end-to-end on the fast tiny preset.
//!
//!     cargo run --release -p fedhc --example quickstart
//!
//! Builds a 24-satellite constellation, clusters it with the paper's
//! satellite-clustered PS selection, trains hierarchically with MAML-driven
//! re-clustering, and prints the per-round accuracy/time/energy series.
//! Uses the AOT/PJRT artifacts when present and the built-in pure-Rust
//! host backend otherwise, so it works out of the box.

use anyhow::Result;
use fedhc::config::ExperimentConfig;
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::runtime::{Manifest, ModelRuntime};

fn main() -> Result<()> {
    let cfg = ExperimentConfig::tiny();
    let manifest = Manifest::load_or_host(&Manifest::default_dir())?;
    let rt = ModelRuntime::load(&manifest, cfg.variant())?;
    println!(
        "quickstart: {} clients, K={}, {} rounds, platform={}",
        cfg.clients,
        cfg.clusters,
        cfg.rounds,
        rt.platform()
    );

    let mut trial = Trial::new(cfg, &manifest, &rt)?;
    let res = run_clustered(&mut trial, Strategy::fedhc())?;

    println!("\nround   time(s)   energy(J)   accuracy    loss");
    for r in &res.ledger.records {
        println!(
            "{:>5} {:>9.2} {:>11.2} {:>10.2}% {:>7.3}",
            r.round,
            r.time_s,
            r.energy_j,
            r.accuracy * 100.0,
            r.loss
        );
    }
    println!(
        "\nbest accuracy {:.2}%  |  {} re-clusterings, {} MAML warm-starts",
        res.final_accuracy * 100.0,
        res.ledger.reclusters,
        res.ledger.maml_adaptations
    );
    Ok(())
}

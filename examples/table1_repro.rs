//! Table I reproduction: time + energy to target accuracy for all four
//! methods × K ∈ {3,4,5} on one dataset.
//!
//!     cargo run --release --example table1_repro [tiny|mnist|cifar10] [--fast]
//!
//! Configurations are independent, so each (method, K) cell runs in its own
//! OS thread with its own PJRT runtime (the xla client is not Sync).
//! `--fast` shrinks the workload so the table regenerates in minutes; the
//! full preset matches EXPERIMENTS.md.

use fedhc::baselines::run_cfedavg;
use fedhc::config::ExperimentConfig;
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::metrics::report::{format_table1, TimeEnergy};
use fedhc::runtime::{Manifest, ModelRuntime};

const METHODS: &[&str] = &["C-FedAvg", "H-BASE", "FedCE", "FedHC"];

fn run_cell(cfg: ExperimentConfig, method: &'static str) -> anyhow::Result<TimeEnergy> {
    // per-thread runtime: the PJRT client is thread-local by construction
    let manifest = Manifest::load_or_host(&Manifest::default_dir())?;
    let rt = ModelRuntime::load(&manifest, cfg.variant())?;
    let mut trial = Trial::new(cfg, &manifest, &rt)?;
    let res = match method {
        "C-FedAvg" => run_cfedavg(&mut trial)?,
        "H-BASE" => run_clustered(&mut trial, Strategy::hbase())?,
        "FedCE" => run_clustered(&mut trial, Strategy::fedce())?,
        "FedHC" => run_clustered(&mut trial, Strategy::fedhc())?,
        _ => unreachable!(),
    };
    Ok(match res.converged_at {
        Some((_, t, e)) => TimeEnergy { time_s: t, energy_j: e, converged: true },
        None => TimeEnergy {
            time_s: res.ledger.time_s,
            energy_j: res.ledger.energy_j,
            converged: false,
        },
    })
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .map(|s| s.as_str())
        .unwrap_or("tiny");
    let fast = args.iter().any(|a| a == "--fast") || preset == "tiny";
    let mut base = ExperimentConfig::preset(preset).expect("unknown preset");
    if preset == "tiny" {
        base.target_accuracy = Some(0.6);
        base.rounds = 40;
    }
    if fast && preset != "tiny" {
        // single-core-image scale: 16 clients × 256 samples; the target is
        // lowered with the scale (fewer clients → noisier aggregate) — the
        // paper-scale run is the default (no --fast) configuration
        base.clients = 16;
        base.train_samples = 4096;
        base.test_samples = 256;
        base.rounds = 25;
        base.eval_batches = 2;
        base.lr = 0.15;
        base.dirichlet_alpha = 1.0;
        base.target_accuracy = Some(if base.dataset == fedhc::data::DatasetKind::Cifar10 {
            0.30
        } else {
            0.60
        });
    }
    // optional positional round budget: table1_repro mnist --fast 15
    if let Some(r) = args.iter().filter_map(|a| a.parse::<usize>().ok()).next() {
        base.rounds = r;
    }
    let ks = [3usize, 4, 5];
    let target = base.target_accuracy.unwrap_or(0.8);
    eprintln!(
        "table1 ({preset}{}): {} methods × K={ks:?}, target {:.0}%",
        if fast { ", fast" } else { "" },
        METHODS.len(),
        target * 100.0
    );

    // spawn one thread per cell
    let mut handles = Vec::new();
    for &method in METHODS {
        for &k in &ks {
            let mut cfg = base.clone();
            cfg.clusters = k;
            handles.push((
                method,
                k,
                std::thread::spawn(move || run_cell(cfg, method)),
            ));
        }
    }
    let mut cells: std::collections::BTreeMap<(&str, usize), TimeEnergy> = Default::default();
    for (method, k, h) in handles {
        let cell = h.join().expect("worker panicked")?;
        eprintln!(
            "  {method:<9} K={k}: t={:.0}s e={:.0}J{}",
            cell.time_s,
            cell.energy_j,
            if cell.converged { "" } else { " (budget)" }
        );
        cells.insert((method, k), cell);
    }

    let rows: Vec<(&str, Vec<TimeEnergy>)> = METHODS
        .iter()
        .map(|&m| (m, ks.iter().map(|&k| cells[&(m, k)]).collect()))
        .collect();
    println!("\n{}", format_table1(base.dataset.name(), target, &ks, &rows));
    Ok(())
}

//! Constellation tour: exercises the orbital substrate on its own.
//!
//! Prints the paper-shell Walker constellation, ground-station visibility
//! windows over one orbital period, and how satellite clusters (Eq. 13–15)
//! decay as the constellation rotates — the churn that drives FedHC's
//! re-clustering trigger.

use fedhc::clustering::kmeans::KMeans;
use fedhc::clustering::recluster::changed_members;
use fedhc::orbit::geo::default_ground_segment;
use fedhc::orbit::index::SphereGrid;
use fedhc::orbit::propagate::Constellation;
use fedhc::orbit::visibility::{visible_sats, visible_sats_indexed, windows};
use fedhc::orbit::walker::WalkerConstellation;
use fedhc::util::Rng;

fn main() {
    let walker = WalkerConstellation::paper_shell(8, 12);
    let c = Constellation::from_walker(&walker);
    let period = c.min_period();
    println!(
        "Walker shell: {} sats, {} planes × {} slots, alt 1300 km, incl 53°",
        c.len(),
        walker.planes,
        walker.sats_per_plane
    );
    println!(
        "orbital period: {:.1} min, speed {:.2} km/s\n",
        period / 60.0,
        c.elements[0].speed() / 1e3
    );

    // ground-station visibility — probed through the constellation
    // plane's sphere grid, cross-checked against the exhaustive scan
    let snap0 = c.snapshot(0.0);
    let grid = SphereGrid::build(&snap0.features_km(), SphereGrid::auto_bands(c.len()));
    for gs in default_ground_segment() {
        let now = visible_sats_indexed(&gs, &snap0, &grid);
        assert_eq!(now, visible_sats(&gs, &c, 0.0), "index must be exact");
        let ws = windows(&gs, &c, 0.0, period, 30.0);
        let mean_pass = if ws.is_empty() {
            0.0
        } else {
            ws.iter().map(|w| w.duration()).sum::<f64>() / ws.len() as f64
        };
        println!(
            "{:<10} ({:>6.1}°, {:>7.1}°): sees {:>2} sats now; {:>3} passes/orbit, mean {:>5.1} min",
            gs.name,
            gs.lat_deg,
            gs.lon_deg,
            now.len(),
            ws.len(),
            mean_pass / 60.0
        );
    }

    // cluster decay over a quarter orbit
    println!("\ncluster decay (K=5, Eq. 13–15 clustering frozen at t=0):");
    let mut rng = Rng::new(7);
    let feats0 = c.snapshot(0.0).features_km();
    let res = KMeans::new(5).run(&feats0, &mut rng).expect("kmeans");
    println!("  t=0: sizes {:?}, inertia {:.0}", res.sizes(), res.inertia);
    for pct in [5, 10, 15, 20, 25] {
        let t = period * pct as f64 / 100.0;
        let feats = c.snapshot(t).features_km();
        // natural assignment at time t against the frozen centroids
        let natural: Vec<usize> = feats
            .iter()
            .map(|f| {
                (0..5)
                    .min_by(|&a, &b| {
                        let da: f64 = (0..3)
                            .map(|d| (f[d] - res.centroids[a][d]).powi(2))
                            .sum();
                        let db: f64 = (0..3)
                            .map(|d| (f[d] - res.centroids[b][d]).powi(2))
                            .sum();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap()
            })
            .collect();
        let moved = changed_members(&res.assignment, &natural).len();
        println!(
            "  t={:>4.1} min: {:>2}/{} satellites drifted out of their cluster ({:.0}% dropout)",
            t / 60.0,
            moved,
            c.len(),
            100.0 * moved as f64 / c.len() as f64
        );
    }
    println!("\n(a dropout rate above Z triggers FedHC's re-clustering + MAML warm start)");
}
